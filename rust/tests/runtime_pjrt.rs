//! PJRT-backed integration: AOT JAX/Pallas artifacts loaded and executed
//! from Rust, plus the real two-worker co-execution engine.
//!
//! Requires `make artifacts` (run from the repo root so `artifacts/`
//! resolves; `COEXEC_ARTIFACTS` overrides).

use mobile_coexec::coexec::CoexecEngine;
use mobile_coexec::device::noise::SplitMix64;
use mobile_coexec::device::SyncMechanism;
use mobile_coexec::runtime::{read_manifest, Runtime};

fn artifacts_ready() -> bool {
    Runtime::default_dir().join("manifest.tsv").exists()
}

fn randvec(rng: &mut SplitMix64, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 0.2).collect()
}

fn cpu_matmul(x: &[f32], w: &[f32], b: Option<&[f32]>, m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let xv = x[i * k + kk];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            let yrow = &mut y[i * n..(i + 1) * n];
            for j in 0..n {
                yrow[j] += xv * wrow[j];
            }
        }
    }
    if let Some(b) = b {
        for i in 0..m {
            for j in 0..n {
                y[i * n + j] += b[j];
            }
        }
    }
    y
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    let mut max_err = 0.0f32;
    for (a, b) in got.iter().zip(want) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < tol, "{what}: max err {max_err}");
}

#[test]
fn manifest_lists_all_artifacts() {
    assert!(artifacts_ready(), "run `make artifacts` first");
    let m = read_manifest(&Runtime::default_dir()).unwrap();
    assert!(m.len() >= 20, "only {} artifacts", m.len());
    for name in ["linear_full", "linear_cpu_c592", "linear_gpu_c592", "conv3x3_full", "conv3x3_winograd", "vit_mlp_block_c592"] {
        assert!(m.iter().any(|a| a.name == name), "missing {name}");
    }
}

#[test]
fn aot_linear_matches_native_gemm() {
    assert!(artifacts_ready(), "run `make artifacts` first");
    let rt = Runtime::cpu(Runtime::default_dir()).unwrap();
    let (l, cin, cout) = (50, 768, 3072);
    let mut rng = SplitMix64::new(10);
    let x = randvec(&mut rng, l * cin);
    let w = randvec(&mut rng, cin * cout);
    let b = randvec(&mut rng, cout);
    let got = rt
        .execute_artifact(
            "linear_full",
            &[(&x, &[l, cin][..]), (&w, &[cin, cout][..]), (&b, &[cout][..])],
        )
        .unwrap();
    let want = cpu_matmul(&x, &w, Some(&b), l, cin, cout);
    assert_close(&got, &want, 2e-3, "linear_full (Pallas GEMM via PJRT)");
}

#[test]
fn aot_partition_slices_reassemble() {
    // The co-execution identity executed through the real AOT path:
    // cpu slice ++ gpu slice == full output.
    assert!(artifacts_ready(), "run `make artifacts` first");
    let rt = Runtime::cpu(Runtime::default_dir()).unwrap();
    let (l, cin, cout, c1) = (50, 768, 3072, 592);
    let mut rng = SplitMix64::new(11);
    let x = randvec(&mut rng, l * cin);
    let w = randvec(&mut rng, cin * cout);
    let b = randvec(&mut rng, cout);
    let args = [(&x[..], &[l, cin][..]), (&w[..], &[cin, cout][..]), (&b[..], &[cout][..])];
    let full = rt.execute_artifact("linear_full", &args).unwrap();
    let cpu = rt.execute_artifact("linear_cpu_c592", &args).unwrap();
    let gpu = rt.execute_artifact("linear_gpu_c592", &args).unwrap();
    assert_eq!(cpu.len(), l * c1);
    assert_eq!(gpu.len(), l * (cout - c1));
    let mut merged = vec![0.0f32; l * cout];
    for r in 0..l {
        merged[r * cout..r * cout + c1].copy_from_slice(&cpu[r * c1..(r + 1) * c1]);
        merged[r * cout + c1..(r + 1) * cout]
            .copy_from_slice(&gpu[r * (cout - c1)..(r + 1) * (cout - c1)]);
    }
    assert_close(&merged, &full, 1e-3, "partition slices vs fused");
}

#[test]
fn builder_gemm_matches_native() {
    let rt = Runtime::cpu(Runtime::default_dir()).unwrap();
    let (m, k, n) = (17, 33, 29);
    let mut rng = SplitMix64::new(12);
    let x = randvec(&mut rng, m * k);
    let w = randvec(&mut rng, k * n);
    let exe = rt.build_gemm(m, k, n).unwrap();
    let got = rt.execute_raw(&exe, &[(&x, &[m, k][..]), (&w, &[k, n][..])]).unwrap();
    let want = cpu_matmul(&x, &w, None, m, k, n);
    assert_close(&got, &want, 1e-4, "builder gemm");
    // slice path
    let exe2 = rt.build_gemm_slice(m, k, n, 5, 20).unwrap();
    let got2 = rt.execute_raw(&exe2, &[(&x, &[m, k][..]), (&w, &[k, n][..])]).unwrap();
    for r in 0..m {
        for c in 0..15 {
            let full_idx = r * n + 5 + c;
            assert!((got2[r * 15 + c] - want[full_idx]).abs() < 1e-4);
        }
    }
}

#[test]
fn coexec_engine_real_run_verified() {
    assert!(artifacts_ready(), "run `make artifacts` first");
    let engine = CoexecEngine::with_default_artifacts().unwrap();
    let (l, cin, cout, c1) = (50usize, 768usize, 3072usize, 592usize);
    let mut rng = SplitMix64::new(13);
    let x = randvec(&mut rng, l * cin);
    let w = randvec(&mut rng, cin * cout);
    let b = randvec(&mut rng, cout);
    let split = Some(("linear_cpu_c592".to_string(), "linear_gpu_c592".to_string()));
    for mech in [SyncMechanism::SvmPolling, SyncMechanism::EventWait] {
        let (y, report) = engine
            .run_linear(&x, &w, &b, (l, cin, cout), c1, mech, split.clone())
            .unwrap();
        let want = cpu_matmul(&x, &w, Some(&b), l, cin, cout);
        assert_close(&y, &want, 2e-3, "coexec output");
        assert!(report.wall_us > 0.0);
        assert!(report.cpu.exec_us > 0.0 && report.gpu.exec_us > 0.0);
    }
}

#[test]
fn coexec_engine_builder_fallback() {
    // No artifact for c1=1000: the engine must fall back to XlaBuilder
    // slices and still be correct.
    let engine = CoexecEngine::with_default_artifacts().unwrap();
    let (l, cin, cout, c1) = (16usize, 64usize, 96usize, 40usize);
    let mut rng = SplitMix64::new(14);
    let x = randvec(&mut rng, l * cin);
    let w = randvec(&mut rng, cin * cout);
    let b = randvec(&mut rng, cout);
    let (y, _) = engine
        .run_linear(&x, &w, &b, (l, cin, cout), c1, SyncMechanism::SvmPolling, None)
        .unwrap();
    let want = cpu_matmul(&x, &w, Some(&b), l, cin, cout);
    assert_close(&y, &want, 1e-3, "builder-fallback coexec");
}

#[test]
fn winograd_artifact_matches_direct_conv() {
    // L1 Winograd Pallas kernel vs the direct conv kernel, both through
    // the full AOT -> PJRT path.
    assert!(artifacts_ready(), "run `make artifacts` first");
    let rt = Runtime::cpu(Runtime::default_dir()).unwrap();
    let (h, w_, cin, cout) = (64, 64, 128, 192);
    let mut rng = SplitMix64::new(15);
    let x = randvec(&mut rng, h * w_ * cin);
    let w = randvec(&mut rng, 3 * 3 * cin * cout);
    let args = [(&x[..], &[1, h, w_, cin][..]), (&w[..], &[3, 3, cin, cout][..])];
    let direct = rt.execute_artifact("conv3x3_full", &args).unwrap();
    let wino = rt.execute_artifact("conv3x3_winograd", &args).unwrap();
    assert_close(&wino, &direct, 5e-2, "winograd vs direct conv (AOT)");
}

#[test]
fn vit_block_artifact_runs() {
    assert!(artifacts_ready(), "run `make artifacts` first");
    let rt = Runtime::cpu(Runtime::default_dir()).unwrap();
    let mut rng = SplitMix64::new(16);
    let x = randvec(&mut rng, 50 * 768);
    let w1 = randvec(&mut rng, 768 * 3072);
    let b1 = randvec(&mut rng, 3072);
    let w2 = randvec(&mut rng, 3072 * 768);
    let b2 = randvec(&mut rng, 768);
    let y = rt
        .execute_artifact(
            "vit_mlp_block_c592",
            &[
                (&x, &[50, 768][..]),
                (&w1, &[768, 3072][..]),
                (&b1, &[3072][..]),
                (&w2, &[3072, 768][..]),
                (&b2, &[768][..]),
            ],
        )
        .unwrap();
    assert_eq!(y.len(), 50 * 768);
    assert!(y.iter().all(|v| v.is_finite()));
}
