//! Loopback tests for the parallel cold-planning paths and the
//! `plan.hit` / `plan.miss` telemetry split.
//!
//! The worker-pool fan-out behind `PLAN_MODEL` and cold `PLAN_BATCH`
//! must be *invisible* except in wall-clock time: replies byte-identical
//! to a pool-less (serial) state handling the same lines, cache counters
//! exact, and the `STATS` grammar stable. These tests pin that by
//! running every request against two identically constructed states —
//! one driven directly (no pool attached, so planning is serial) and one
//! served over loopback through the evented front-end (pool attached, so
//! cold multi-op requests fan out).

use mobile_coexec::device::Device;
use mobile_coexec::server::{Server, ServerConfig, ServerState};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

fn spawn(state: Arc<ServerState>) -> SocketAddr {
    Server::new(state, ServerConfig::default()).spawn_ephemeral().expect("spawn server")
}

/// Persistent-connection client: sends one line, reads one reply line.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Self { stream, reader }
    }

    fn request(&mut self, line: &str) -> String {
        self.stream.write_all(line.as_bytes()).expect("write");
        self.stream.write_all(b"\n").expect("write nl");
        self.read_line()
    }

    fn read_line(&mut self) -> String {
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("read");
        reply.trim().to_string()
    }

    /// Send a `PLAN_BATCH` line; return all reply lines including the
    /// `OK n=<k>` framing header.
    fn request_batch(&mut self, line: &str) -> Vec<String> {
        let header = self.request(line);
        let n: usize = header
            .strip_prefix("OK n=")
            .unwrap_or_else(|| panic!("bad batch header: {header}"))
            .parse()
            .expect("batch count");
        let mut lines = vec![header];
        lines.extend((0..n).map(|_| self.read_line()));
        lines
    }
}

fn stat(reply: &str, key: &str) -> String {
    reply
        .split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v.to_string())
        .unwrap_or_else(|| panic!("missing {key} in: {reply}"))
}

/// `PLAN_MODEL` through the pool-backed server fans its cold layer
/// shapes across workers; the reply and the cache counters must be
/// byte-for-byte what the serial path produces, cold and warm.
#[test]
fn plan_model_parallel_fan_out_matches_serial_byte_for_byte() {
    // serial reference: no pool attached, planning happens inline
    let serial = ServerState::new(Device::pixel5(), 500, 7);
    let mut session = serial.session();
    let serial_cold = serial.handle(&mut session, "PLAN_MODEL resnet18 2");
    assert!(serial_cold.starts_with("OK model=resnet18"), "unexpected: {serial_cold}");
    let serial_counters = (serial.cache.hits(), serial.cache.misses());
    let serial_warm = serial.handle(&mut session, "PLAN_MODEL resnet18 2");
    assert_eq!(serial_cold, serial_warm, "serial replan must be cache-stable");

    // parallel: identical state, served through the evented front-end
    // with the worker pool attached
    let parallel = Arc::new(ServerState::new(Device::pixel5(), 500, 7));
    let addr = spawn(parallel.clone());
    let mut client = Client::connect(&addr);
    let par_cold = client.request("PLAN_MODEL resnet18 2");
    assert_eq!(par_cold, serial_cold, "parallel cold PLAN_MODEL diverged from serial");
    let (hits_cold, misses_cold) = (parallel.cache.hits(), parallel.cache.misses());
    assert_eq!((hits_cold, misses_cold), serial_counters, "cold-pass counters diverged");

    let par_warm = client.request("PLAN_MODEL resnet18 2");
    assert_eq!(par_warm, serial_cold, "parallel warm PLAN_MODEL diverged");
    assert_eq!(parallel.cache.misses(), misses_cold, "warm replan must not miss");
    assert!(parallel.cache.hits() > hits_cold, "warm replan must hit");
}

/// A cold `PLAN_BATCH` with distinct shapes (including an `auto` axis and
/// an in-band parse error) fans out; the per-op lines, their order, and
/// the hit/miss counters must match the serial path exactly.
#[test]
fn plan_batch_cold_fan_out_matches_serial_byte_for_byte() {
    const BATCH: &str = "PLAN_BATCH linear 50 768 3072 2; conv 56 56 64 128 3 1 2; \
                         linear 197 768 3072 4; conv 28 28 128 256 3 1 auto; \
                         linear 1 512 1000 2; bogus spec; linear 50 768 3072 2";

    let serial = ServerState::new(Device::pixel5(), 500, 7);
    let mut session = serial.session();
    let serial_lines: Vec<String> =
        serial.handle(&mut session, BATCH).lines().map(str::to_string).collect();

    let parallel = Arc::new(ServerState::new(Device::pixel5(), 500, 7));
    let addr = spawn(parallel.clone());
    let mut client = Client::connect(&addr);
    let par_lines = client.request_batch(BATCH);

    assert_eq!(par_lines, serial_lines, "parallel PLAN_BATCH diverged from serial");
    assert_eq!(
        (parallel.cache.hits(), parallel.cache.misses()),
        (serial.cache.hits(), serial.cache.misses()),
        "parallel PLAN_BATCH counters diverged from serial"
    );
    // the trailing repeat of the first spec must have been a warm hit,
    // not a second plan
    assert_eq!(par_lines.last(), Some(&par_lines[1]));
    assert!(par_lines[6].starts_with("ERR "), "in-band error lost: {}", par_lines[6]);

    // replaying the whole batch is all-warm: zero new misses either way
    let misses = parallel.cache.misses();
    let replay = client.request_batch(BATCH);
    assert_eq!(replay, par_lines);
    assert_eq!(parallel.cache.misses(), misses);
}

/// Satellite telemetry: the `PLAN` verb's latency splits into `plan.hit`
/// and `plan.miss` sub-endpoints so the ~µs warm population stops hiding
/// the planner-sweep cold population (and vice versa) in one blended
/// percentile. The split blocks ride between `plan.*` and
/// `plan_batch.*` in `STATS`, and the evented fast path feeds the hit
/// side too.
#[test]
fn stats_split_plan_latency_by_cache_outcome() {
    let state = Arc::new(ServerState::new(Device::pixel5(), 500, 11));
    let addr = spawn(state.clone());
    let mut client = Client::connect(&addr);

    let stats0 = client.request("STATS");
    assert_eq!(stat(&stats0, "plan.hit.req"), "0");
    assert_eq!(stat(&stats0, "plan.miss.req"), "0");

    let cold = client.request("PLAN linear 50 768 1024 2");
    assert!(cold.starts_with("OK "), "unexpected: {cold}");
    let stats1 = client.request("STATS");
    assert_eq!(stat(&stats1, "plan.miss.req"), "1");
    assert_eq!(stat(&stats1, "plan.hit.req"), "0");

    // warm repeats are served by the evented fast path, which must feed
    // plan.hit (the pool path's traced planner would, too)
    let w1 = client.request("PLAN linear 50 768 1024 2");
    let w2 = client.request("PLAN linear 50 768 1024 2");
    assert_eq!(w1, cold);
    assert_eq!(w2, cold);
    let stats2 = client.request("STATS");
    assert_eq!(stat(&stats2, "plan.miss.req"), "1");
    assert_eq!(stat(&stats2, "plan.hit.req"), "2");
    assert_eq!(stat(&stats2, "plan.hit.err"), "0");
    assert_eq!(stat(&stats2, "plan.miss.err"), "0");

    // grammar: the split blocks sit between plan.* and plan_batch.*
    let pos = |k: &str| stats2.find(k).unwrap_or_else(|| panic!("missing {k}"));
    assert!(pos("plan.req=") < pos("plan.hit.req="));
    assert!(pos("plan.hit.req=") < pos("plan.miss.req="));
    assert!(pos("plan.miss.req=") < pos("plan_batch.req="));

    // a full-auto request (which also kicks the background placement
    // prewarm off the critical path) stays deterministic: the warm
    // repeat is byte-identical and lands on the hit side
    let a1 = client.request("PLAN linear 64 512 2048 auto cluster=auto");
    let a2 = client.request("PLAN linear 64 512 2048 auto cluster=auto");
    assert_eq!(a1, a2, "cluster-auto replan diverged");
    let stats3 = client.request("STATS");
    assert_eq!(stat(&stats3, "plan.miss.req"), "2");
    assert_eq!(stat(&stats3, "plan.hit.req"), "3");
}
