//! Randomized property tests over coordinator invariants.
//!
//! proptest is unavailable in the offline build, so these sweeps use the
//! crate's own seeded PRNG: hundreds of random cases per property, fully
//! deterministic, with the failing case printed on assert.

use mobile_coexec::device::noise::SplitMix64;
use mobile_coexec::device::{ClusterId, Device, ReqImpl, SyncMechanism};
use mobile_coexec::gbdt::{Gbdt, GbdtParams};
use mobile_coexec::metrics;
use mobile_coexec::ops::{ChannelSplit, ConvConfig, LinearConfig, OpConfig, Partitionable};

fn random_linear(rng: &mut SplitMix64) -> LinearConfig {
    LinearConfig::new(rng.gen_range(1, 2048), rng.gen_range(1, 2048), rng.gen_range(2, 4096))
}

fn random_conv(rng: &mut SplitMix64) -> ConvConfig {
    ConvConfig::new(
        rng.gen_range(4, 128),
        rng.gen_range(4, 128),
        rng.gen_range(1, 512),
        rng.gen_range(2, 512),
        [1, 3, 5, 7][rng.gen_range(0, 3)],
        [1, 2][rng.gen_range(0, 1)],
    )
}

fn random_op(rng: &mut SplitMix64) -> OpConfig {
    if rng.next_f64() < 0.5 {
        OpConfig::Linear(random_linear(rng))
    } else {
        OpConfig::Conv(random_conv(rng))
    }
}

/// Property: splitting preserves channel totals and FLOPs additivity.
#[test]
fn prop_split_preserves_flops() {
    let mut rng = SplitMix64::new(1);
    for case in 0..500 {
        let op = random_op(&mut rng);
        let cout = op.cout();
        let c1 = rng.gen_range(1, cout - 1);
        let split = ChannelSplit::new(c1, cout - c1);
        let (cpu, gpu) = op.split(split);
        let (cpu, gpu) = (cpu.unwrap(), gpu.unwrap());
        assert_eq!(cpu.cout() + gpu.cout(), cout, "case {case}: {op}");
        let sum = cpu.flops() + gpu.flops();
        assert!(
            (sum - op.flops()).abs() / op.flops() < 1e-9,
            "case {case}: flops not additive for {op} at c1={c1}"
        );
    }
}

/// Property: co-execution latency is bounded below by each side's own
/// latency and above by exclusive execution + overhead... specifically
/// max(sides) <= coexec <= max(sides) + overhead*(1+5*sigma).
#[test]
fn prop_coexec_latency_bounds() {
    let mut rng = SplitMix64::new(2);
    let devices = Device::all();
    for case in 0..200 {
        let device = &devices[rng.gen_range(0, devices.len() - 1)];
        let op = random_op(&mut rng);
        let cout = op.cout();
        let c1 = rng.gen_range(1, cout - 1);
        let split = ChannelSplit::new(c1, cout - c1);
        let threads = rng.gen_range(1, 2);
        // the latency bound holds on every cluster, not just prime
        let clusters = &device.spec.cpu.clusters;
        let cluster = clusters[rng.gen_range(0, clusters.len() - 1)].id;
        let trial = case as u64;
        let t_cpu = device.measure_cpu(&op.with_cout(c1), cluster, threads, trial);
        let t_gpu = device.measure_gpu(&op.with_cout(cout - c1), trial);
        let t_co =
            device.measure_coexec(&op, split, cluster, threads, SyncMechanism::SvmPolling, trial);
        let floor = t_cpu.max(t_gpu);
        let ceil = floor + device.sync_overhead_us(SyncMechanism::SvmPolling, op.kind()) * 3.0;
        assert!(
            t_co >= floor && t_co <= ceil,
            "case {case} {op}: co {t_co:.1} outside [{floor:.1}, {ceil:.1}]"
        );
    }
}

/// Property: exclusive execution has exactly zero sync overhead.
#[test]
fn prop_exclusive_no_overhead() {
    let mut rng = SplitMix64::new(3);
    let device = Device::moto2022();
    for case in 0..200 {
        let op = random_op(&mut rng);
        let trial = case as u64;
        let gpu_only = device.measure_coexec(
            &op,
            ChannelSplit::gpu_only(op.cout()),
            ClusterId::Prime,
            1,
            SyncMechanism::EventWait,
            trial,
        );
        assert_eq!(gpu_only, device.measure_gpu(&op, trial), "case {case} {op}");
    }
}

/// Property: GPU dispatch decisions are internally consistent.
#[test]
fn prop_dispatch_consistency() {
    let mut rng = SplitMix64::new(4);
    let device = Device::oneplus11();
    for case in 0..500 {
        let op = random_op(&mut rng);
        let d = device.gpu_dispatch(&op);
        assert_eq!(
            d.wg_count,
            d.out_slices.div_ceil(d.wg_x) * d.row_tiles.div_ceil(d.wg_y),
            "case {case} {op}: wg_count inconsistent"
        );
        assert_eq!(
            d.waves,
            d.wg_count.div_ceil(device.spec.gpu.compute_units),
            "case {case} {op}: waves inconsistent"
        );
        assert!(d.waste >= 0.0, "case {case}: negative waste");
        let (lat, d2) = device.gpu_model_us(&op);
        assert!(lat.is_finite() && lat > 0.0);
        assert_eq!(d, d2, "dispatch must be deterministic");
    }
}

/// Property: CPU latency is monotone in output channels at tile
/// granularity (adding a whole NR tile never reduces latency).
#[test]
fn prop_cpu_monotone_in_tiles() {
    let mut rng = SplitMix64::new(5);
    let device = Device::pixel4();
    for case in 0..300 {
        let cfg = random_linear(&mut rng);
        if cfg.cout < 16 {
            continue;
        }
        let smaller = OpConfig::Linear(cfg.with_cout(cfg.cout - 8));
        let bigger = OpConfig::Linear(cfg);
        let cluster = device.spec.cpu.clusters[case % device.spec.cpu.clusters.len()].id;
        let t_small = device.cpu_model_us(&smaller, cluster, 2);
        let t_big = device.cpu_model_us(&bigger, cluster, 2);
        assert!(
            t_big >= t_small - 1e-9,
            "case {case}: cpu latency decreased {t_small} -> {t_big} for {bigger}"
        );
    }
}

/// Property: GBDT predictions are finite and reproduce training behaviour
/// for arbitrary feature matrices.
#[test]
fn prop_gbdt_finite_predictions() {
    let mut rng = SplitMix64::new(6);
    for case in 0..20 {
        let n = rng.gen_range(50, 400);
        let d = rng.gen_range(1, 6);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.next_f64() * 1000.0 - 500.0).collect())
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| r.iter().sum::<f64>().abs() + 1.0).collect();
        let params = GbdtParams { n_estimators: 30, ..Default::default() };
        let m = Gbdt::fit(&rows, &y, &params);
        for r in rows.iter().take(20) {
            let p = m.predict(r);
            assert!(p.is_finite(), "case {case}: non-finite prediction");
        }
        // out-of-range queries must also be finite (extrapolation clamps)
        let far: Vec<f64> = (0..d).map(|_| 1e9).collect();
        assert!(m.predict(&far).is_finite());
    }
}

/// Property: metrics helpers agree with naive definitions.
#[test]
fn prop_metrics_agree_with_naive() {
    let mut rng = SplitMix64::new(7);
    for _ in 0..100 {
        let n = rng.gen_range(2, 50);
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 100.0 + 1.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x * (0.8 + 0.4 * rng.next_f64())).collect();
        let naive = xs
            .iter()
            .zip(&ys)
            .map(|(a, p)| ((p - a) / a).abs())
            .sum::<f64>()
            / n as f64;
        assert!((metrics::mape(&xs, &ys) - naive).abs() < 1e-12);
        let m = metrics::mean(&xs);
        assert!((m - xs.iter().sum::<f64>() / n as f64).abs() < 1e-12);
        let lo = metrics::percentile(&xs, 0.0).expect("non-empty");
        let hi = metrics::percentile(&xs, 100.0).expect("non-empty");
        assert!(lo <= hi);
    }
}

/// Property: an `Auto` plan's predicted total is never worse than any
/// fixed `(threads, mech)` plan for the same op — the strategy search's
/// pruning (analytic mechanism collapse, per-candidate dominated-thread
/// skips) must never discard a candidate that could have won.
#[test]
fn prop_auto_plan_never_worse_than_any_fixed_strategy() {
    use mobile_coexec::partition::{PlanRequest, Planner};

    let device = Device::pixel5();
    let linear = Planner::train_for_kind(&device, "linear", 600, 31);
    let conv = Planner::train_for_kind(&device, "conv", 600, 31);
    let max_threads = device.spec.cpu.max_threads();
    let mut rng = SplitMix64::new(12);
    for case in 0..40 {
        let op = random_op(&mut rng);
        let planner = match op {
            OpConfig::Linear(_) => &linear,
            OpConfig::Conv(_) => &conv,
        };
        let auto = planner.plan_request(&op, PlanRequest::auto());
        assert!(
            (1..=max_threads).contains(&auto.threads),
            "case {case} {op}: auto resolved threads {}",
            auto.threads
        );
        for threads in 1..=max_threads {
            for mech in [SyncMechanism::SvmPolling, SyncMechanism::EventWait] {
                let fixed = planner.plan_request(&op, PlanRequest::fixed(threads, mech));
                assert!(
                    auto.t_total_us <= fixed.t_total_us + 1e-9,
                    "case {case} {op}: auto {:.3}us worse than fixed ({threads}, {mech:?}) {:.3}us",
                    auto.t_total_us,
                    fixed.t_total_us
                );
            }
        }
        // the auto plan *is* one of the fixed plans (exactness, not just
        // dominance): re-planning at its resolved strategy reproduces it
        let replay =
            planner.plan_request(&op, PlanRequest::fixed(auto.threads, auto.mech));
        assert_eq!(replay, auto, "case {case} {op}: auto plan not reproducible");
    }
}

/// Property: a cluster-`Auto` plan's predicted total is never worse than
/// any fixed `(cluster, threads, mech)` plan for the same op — the 4-axis
/// joint search's pruning (analytic mechanism collapse, per-candidate
/// dominated-placement skips, shared GPU predictions) must never discard
/// a candidate that could have won on *any* cluster — and the plan is
/// exactly reproducible at its resolved strategy.
#[test]
fn prop_cluster_auto_never_worse_than_any_fixed_placement() {
    use mobile_coexec::partition::{PlanRequest, Planner};

    let device = Device::pixel5();
    let linear = Planner::train_for_kind(&device, "linear", 600, 31);
    let conv = Planner::train_for_kind(&device, "conv", 600, 31);
    let mut rng = SplitMix64::new(14);
    for case in 0..12 {
        // mix random shapes with tiny launch-bound ones, where the little
        // clusters' cheaper wake-up actually wins placements
        let op = if case % 3 == 0 {
            OpConfig::Linear(LinearConfig::new(
                rng.gen_range(1, 8),
                rng.gen_range(1, 32),
                rng.gen_range(2, 64),
            ))
        } else {
            random_op(&mut rng)
        };
        let planner = match op {
            OpConfig::Linear(_) => &linear,
            OpConfig::Conv(_) => &conv,
        };
        let auto = planner.plan_request(&op, PlanRequest::cluster_auto());
        let budget = device
            .spec
            .cpu
            .cluster(auto.cluster)
            .expect("resolved cluster exists on the device")
            .max_threads();
        assert!(
            (1..=budget).contains(&auto.threads),
            "case {case} {op}: resolved {} threads outside the {} budget",
            auto.threads,
            auto.cluster
        );
        for cl in &device.spec.cpu.clusters {
            for threads in 1..=cl.max_threads() {
                for mech in [SyncMechanism::SvmPolling, SyncMechanism::EventWait] {
                    let fixed =
                        planner.plan_request(&op, PlanRequest::fixed_on(cl.id, threads, mech));
                    assert!(
                        auto.t_total_us <= fixed.t_total_us + 1e-9,
                        "case {case} {op}: cluster-auto {:.3}us worse than fixed \
                         ({}, {threads}, {mech:?}) {:.3}us",
                        auto.t_total_us,
                        cl.id,
                        fixed.t_total_us
                    );
                }
            }
        }
        // the auto plan *is* one of the fixed plans (exactness, not just
        // dominance): re-planning at its resolved strategy reproduces it
        let s = auto.strategy();
        let replay = planner.plan_request(&op, PlanRequest::fixed_on(s.cluster, s.threads, s.mech));
        assert_eq!(replay, auto, "case {case} {op}: cluster-auto plan not reproducible");
    }
}

/// Property: a 5-axis `impl=auto` plan's predicted total is never worse
/// than any fixed `(cluster, threads, mech, impl)` strategy for the same
/// op — the joint search's impl-eligibility prune must never discard a
/// kernel implementation that could have won — it is *exactly* the best
/// of them (equal predicted cost, so the auto axis is a minimization,
/// not an approximation), and re-planning at its resolved strategy
/// reproduces the plan bit for bit.
#[test]
fn prop_impl_auto_never_worse_than_any_fixed_impl() {
    use mobile_coexec::partition::{Choice, PlanRequest, Planner};

    let device = Device::pixel5();
    let linear = Planner::train_for_kind(&device, "linear", 600, 31);
    let conv = Planner::train_for_kind(&device, "conv", 600, 31);
    let mut rng = SplitMix64::new(23);
    for case in 0..8 {
        // mix random shapes with winograd-friendly 3x3 stride-1 convs so
        // the impl axis genuinely competes
        let op = if case % 2 == 0 {
            OpConfig::Conv(ConvConfig::new(
                rng.gen_range(8, 64),
                rng.gen_range(8, 64),
                rng.gen_range(8, 256),
                rng.gen_range(8, 256),
                3,
                1,
            ))
        } else {
            random_op(&mut rng)
        };
        let planner = match op {
            OpConfig::Linear(_) => &linear,
            OpConfig::Conv(_) => &conv,
        };
        let auto =
            planner.plan_request(&op, PlanRequest::cluster_auto().with_impl(Choice::Auto));
        assert!(
            auto.imp.eligible(&op),
            "case {case} {op}: auto resolved an ineligible impl {:?}",
            auto.imp
        );
        let mut best_fixed = f64::INFINITY;
        for cl in &device.spec.cpu.clusters {
            for threads in 1..=cl.max_threads() {
                for mech in [SyncMechanism::SvmPolling, SyncMechanism::EventWait] {
                    for imp in ReqImpl::ALL {
                        if !imp.eligible(&op) {
                            continue;
                        }
                        let fixed = planner.plan_request(
                            &op,
                            PlanRequest::fixed_on(cl.id, threads, mech)
                                .with_impl(Choice::Fixed(imp)),
                        );
                        best_fixed = best_fixed.min(fixed.t_total_us);
                        assert!(
                            auto.t_total_us <= fixed.t_total_us + 1e-9,
                            "case {case} {op}: impl-auto {:.3}us worse than fixed \
                             ({}, {threads}, {mech:?}, {imp:?}) {:.3}us",
                            auto.t_total_us,
                            cl.id,
                            fixed.t_total_us
                        );
                    }
                }
            }
        }
        // optimality is exact: auto IS the best fixed strategy's cost
        assert!(
            (auto.t_total_us - best_fixed).abs() <= 1e-9,
            "case {case} {op}: impl-auto {:.6}us != best fixed {:.6}us",
            auto.t_total_us,
            best_fixed
        );
        // and the plan is exactly reproducible at its resolved strategy
        let s = auto.strategy();
        let replay = planner.plan_request(
            &op,
            PlanRequest::fixed_on(s.cluster, s.threads, s.mech).with_impl(Choice::Fixed(s.imp)),
        );
        assert_eq!(replay, auto, "case {case} {op}: impl-auto plan not reproducible");
    }
}

/// Property: the serving layer's plan cache is *transparent* — for random
/// ops, a cached plan is identical to a freshly computed plan — and cache
/// keys never collide across distinct `(op, threads, mech)` tuples.
#[test]
fn prop_plan_cache_transparent_and_keys_collision_free() {
    use mobile_coexec::partition::Planner;
    use mobile_coexec::server::cache::{PlanCache, PlanKey};
    use std::collections::HashSet;

    let device = Device::pixel5();
    let linear = Planner::train_for_kind(&device, "linear", 500, 21);
    let conv = Planner::train_for_kind(&device, "conv", 500, 21);
    let cache = PlanCache::default();
    let mut rng = SplitMix64::new(8);
    let mut tuples: HashSet<(OpConfig, ClusterId, usize, SyncMechanism, ReqImpl)> = HashSet::new();
    let mut keys: HashSet<PlanKey> = HashSet::new();
    for case in 0..60 {
        let op = random_op(&mut rng);
        let threads = rng.gen_range(1, 3);
        let planner = match op {
            OpConfig::Linear(_) => &linear,
            OpConfig::Conv(_) => &conv,
        };
        // transparency: cold fill, then a hit, both == a direct plan
        let cached = cache.get_or_plan(planner, &op, threads);
        let fresh = planner.plan_with_threads(&op, threads);
        assert_eq!(cached, fresh, "case {case}: cold cache fill diverged for {op}");
        let hit = cache.get_or_plan(planner, &op, threads);
        assert_eq!(hit, fresh, "case {case}: cache hit diverged for {op}");
        // key uniqueness: one key per distinct tuple, for both mechanisms,
        // every cluster, and every kernel implementation
        for mech in [SyncMechanism::SvmPolling, SyncMechanism::EventWait] {
            for cluster in ClusterId::ALL {
                for imp in ReqImpl::ALL {
                    tuples.insert((op, cluster, threads, mech, imp));
                    keys.insert(PlanKey {
                        device: device.name(),
                        epoch: 0,
                        op,
                        cluster,
                        threads,
                        mech,
                        imp,
                    });
                }
            }
        }
    }
    assert_eq!(
        keys.len(),
        tuples.len(),
        "distinct (op, cluster, threads, mech, impl) tuples must map to distinct keys"
    );
    // and the cache held exactly one entry per distinct (op, threads)
    // (planning above only touched the prime cluster)
    let planned: HashSet<(OpConfig, usize)> =
        tuples.iter().map(|(op, _, t, _, _)| (*op, *t)).collect();
    assert_eq!(cache.len(), planned.len());
    assert_eq!(cache.misses() as usize, planned.len());
}

/// Property: the TTL x LRU interaction is exact. A shadow model replays
/// every request against the cache's documented semantics — recency on
/// touch, insertion-stamp TTL (a hit must NOT refresh the lease), expired
/// entries dropped before capacity eviction — and must agree with the
/// real cache on every hit/miss. Expiry or eviction never resurrects an
/// entry (a re-request is a fresh miss whose plan is byte-identical to a
/// direct plan), and the counters stay conserved:
/// `misses == live entries + evictions + expired + flushed`.
#[test]
fn prop_ttl_lru_expiry_never_resurrects_and_counters_conserve() {
    use mobile_coexec::partition::{Plan, Planner};
    use mobile_coexec::server::cache::{CacheClock, ManualClock, PlanCache};
    use std::collections::HashMap;
    use std::sync::Arc;
    use std::time::Duration;

    let device = Device::pixel5();
    let planner = Planner::train_for_kind(&device, "linear", 500, 47);
    // a small fixed shape pool so keys collide and churn
    let shapes: Vec<OpConfig> = (0..8)
        .map(|i| OpConfig::Linear(LinearConfig::new(8 + i, 64, 128 + 8 * i)))
        .collect();
    // plans are deterministic: prime the expected plan per (shape, threads)
    let mut expected: HashMap<(usize, usize), Plan> = HashMap::new();
    for (s, op) in shapes.iter().enumerate() {
        for threads in 1..=2 {
            expected.insert((s, threads), planner.plan_with_threads(op, threads));
        }
    }

    let mut rng = SplitMix64::new(13);
    for case in 0..4 {
        let clock = Arc::new(ManualClock::new());
        let ttl_ms = 40 + 40 * case as u64;
        const CAP: usize = 4;
        let cache = PlanCache::with_config(
            1, // one shard: every key contends for the same capacity
            CAP,
            Some(Duration::from_millis(ttl_ms)),
            clock.clone(),
        );
        // shadow model: key -> (insertion stamp, last-use tick)
        let mut shadow: HashMap<(usize, usize), (u64, u64)> = HashMap::new();
        let mut tick = 0u64;
        let mut flushed = 0usize;
        let mut predicted_misses = 0u64;

        for step in 0..120 {
            // jump time by 0-30ms: short next to the TTL sometimes, far
            // past it after a few quiet steps
            clock.advance_ms(rng.gen_range(0, 30) as u64);
            let now = clock.now_ms();
            let key = (rng.gen_range(0, shapes.len() - 1), rng.gen_range(1, 2));
            tick += 1;
            let live = shadow
                .get(&key)
                .is_some_and(|(stamp, _)| now.saturating_sub(*stamp) <= ttl_ms);
            if live {
                shadow.get_mut(&key).unwrap().1 = tick; // recency bump
            } else {
                predicted_misses += 1;
                // the cache drops a touched-but-expired entry first, then
                // purges expired before evicting LRU on a full shard
                shadow.remove(&key);
                shadow.retain(|_, (stamp, _)| now.saturating_sub(*stamp) <= ttl_ms);
                if shadow.len() >= CAP {
                    let lru = *shadow.iter().min_by_key(|(_, (_, t))| *t).map(|(k, _)| k).unwrap();
                    shadow.remove(&lru);
                }
                shadow.insert(key, (now, tick));
            }

            let misses_before = cache.misses();
            let plan = cache.get_or_plan(&planner, &shapes[key.0], key.1);
            assert_eq!(
                plan, expected[&key],
                "case {case} step {step}: a cached/re-planned entry diverged"
            );
            let was_miss = cache.misses() > misses_before;
            assert_eq!(
                was_miss, !live,
                "case {case} step {step}: cache and shadow disagree on hit/miss for {key:?}"
            );

            // occasional full flush, mirrored in the shadow
            if rng.next_f64() < 0.04 {
                flushed += cache.flush();
                shadow.clear();
            }

            // conservation: every miss inserted exactly one entry; entries
            // only leave by eviction, expiry, or flush (len() sweeps, so
            // the live count is exact at observation time)
            assert_eq!(
                cache.misses() as usize,
                cache.len() + cache.evictions() as usize + cache.expired() as usize + flushed,
                "case {case} step {step}: counter conservation violated"
            );
            assert_eq!(cache.misses(), predicted_misses, "case {case} step {step}");
        }
    }
}

/// Property: measurement-driven calibration round-trips. Synthesize a
/// noisy self-profiling campaign from a *random valid* `SocSpec` (a
/// built-in phone with every continuously fitted constant perturbed),
/// fit it against the unperturbed base, and the recovered parameters
/// must land within tolerance of the truth — with the analytic
/// predictions plans are built from (per-side latencies and co-exec
/// totals, across random ops, placements, and mechanisms) within a
/// bounded error of ground truth. Tolerances carry 3-5x margin over the
/// worst observed recovery error across seeds; the weakly identified
/// parameters (cluster bandwidth — few ops are memory-bound) get the
/// loose bounds, which is exactly why the solver regularizes them
/// toward the base instead of letting them chase noise.
#[test]
fn prop_fit_round_trips_random_specs() {
    use mobile_coexec::calibration::{fit_spec, SampleSet};
    use mobile_coexec::device::SocSpec;

    let mut rng = SplitMix64::new(17);
    for case in 0..4u64 {
        // random truth, perturbed field by field (eff entries clamped to
        // stay cumulative-monotone and at most linear)
        let base = SocSpec::pixel5();
        let mut truth = base.clone();
        let scale = |rng: &mut SplitMix64, lo: f64, hi: f64| lo + (hi - lo) * rng.next_f64();
        for cl in &mut truth.cpu.clusters {
            cl.gmacs_per_thread *= scale(&mut rng, 0.75, 1.35);
            cl.mem_bw_gbps *= scale(&mut rng, 0.9, 1.15);
            cl.launch_us *= scale(&mut rng, 0.75, 1.35);
            for n in 2..=cl.efficiency.len() {
                let cand = cl.efficiency[n - 1] * scale(&mut rng, 0.92, 1.05);
                cl.efficiency[n - 1] = cand.clamp(cl.efficiency[n - 2], n as f64);
            }
        }
        truth.gpu.macs_per_cu_cycle *= scale(&mut rng, 0.75, 1.35);
        truth.gpu.mem_bw_gbps *= scale(&mut rng, 0.8, 1.25);
        truth.gpu.dispatch_us *= scale(&mut rng, 0.75, 1.35);
        truth.sync.polling_linear_us *= scale(&mut rng, 0.7, 1.4);
        truth.sync.polling_conv_us *= scale(&mut rng, 0.7, 1.4);
        truth.sync.event_linear_us *= scale(&mut rng, 0.7, 1.4);
        truth.sync.event_conv_us *= scale(&mut rng, 0.7, 1.4);
        truth.validate().unwrap_or_else(|e| panic!("case {case}: perturbed truth invalid: {e}"));

        let device = Device { spec: truth.clone(), seed: 1000 + case, epoch: 0 };
        let samples = SampleSet::synthesize(&device, 12);
        let report = fit_spec(&base, &samples)
            .unwrap_or_else(|e| panic!("case {case}: fit failed: {e}"));
        assert_eq!(
            report.fitted_groups(),
            report.groups.len(),
            "case {case}: every group must fit a full campaign:\n{}",
            report.render()
        );
        let fit = &report.spec;

        // parameter recovery
        let within = |what: &str, got: f64, want: f64, tol: f64| {
            assert!(
                (got / want - 1.0).abs() <= tol,
                "case {case}: {what} fitted {got:.4} vs truth {want:.4} (tol {tol})"
            );
        };
        for (t, f) in truth.cpu.clusters.iter().zip(&fit.cpu.clusters) {
            let w = |field: &str| format!("cpu.{}.{field}", t.id.wire());
            within(&w("gmacs_per_thread"), f.gmacs_per_thread, t.gmacs_per_thread, 0.08);
            within(&w("mem_bw_gbps"), f.mem_bw_gbps, t.mem_bw_gbps, 0.25);
            within(&w("launch_us"), f.launch_us, t.launch_us, 0.08);
            for n in 2..=t.efficiency.len() {
                within(&w(&format!("eff{n}")), f.efficiency[n - 1], t.efficiency[n - 1], 0.08);
            }
        }
        within("gpu.macs_per_cu_cycle", fit.gpu.macs_per_cu_cycle, truth.gpu.macs_per_cu_cycle, 0.05);
        within("gpu.mem_bw_gbps", fit.gpu.mem_bw_gbps, truth.gpu.mem_bw_gbps, 0.20);
        within("gpu.dispatch_us", fit.gpu.dispatch_us, truth.gpu.dispatch_us, 0.05);
        within("sync.polling_linear_us", fit.sync.polling_linear_us, truth.sync.polling_linear_us, 0.30);
        within("sync.polling_conv_us", fit.sync.polling_conv_us, truth.sync.polling_conv_us, 0.30);
        within("sync.event_linear_us", fit.sync.event_linear_us, truth.sync.event_linear_us, 0.30);
        within("sync.event_conv_us", fit.sync.event_conv_us, truth.sync.event_conv_us, 0.30);

        // prediction transfer: the quantities plans minimize stay within
        // bounded error of ground truth on random ops and strategies
        let mut prng = SplitMix64::new(99 + case);
        for probe in 0..40 {
            let op = if prng.next_f64() < 0.5 {
                OpConfig::Linear(LinearConfig::new(
                    prng.gen_range(1, 512),
                    prng.gen_range(1, 1024),
                    prng.gen_range(2, 2048),
                ))
            } else {
                OpConfig::Conv(ConvConfig::new(
                    prng.gen_range(4, 64),
                    prng.gen_range(4, 64),
                    prng.gen_range(1, 256),
                    prng.gen_range(2, 256),
                    [1, 3, 5][prng.gen_range(0, 2)],
                    [1, 2][prng.gen_range(0, 1)],
                ))
            };
            let cid = truth.cpu.clusters[prng.gen_range(0, 2)].id;
            let t = prng.gen_range(1, truth.cpu.cluster(cid).unwrap().max_threads());
            let mech =
                [SyncMechanism::SvmPolling, SyncMechanism::EventWait][prng.gen_range(0, 1)];
            let cpu_us = |spec: &SocSpec, op: &OpConfig| match op {
                OpConfig::Linear(c) => spec.cpu.linear_latency_us(c, cid, t),
                OpConfig::Conv(c) => spec.cpu.conv_latency_us(c, cid, t),
            };
            let gpu_us = |spec: &SocSpec, op: &OpConfig| match op {
                OpConfig::Linear(c) => spec.gpu.linear_latency_us(c).0,
                OpConfig::Conv(c) => spec.gpu.conv_latency_us(c).0,
            };
            let bounded = |what: &str, got: f64, want: f64| {
                assert!(
                    (got / want - 1.0).abs() <= 0.10,
                    "case {case} probe {probe} {op} ({cid}, {t}, {mech:?}): \
                     {what} {got:.2} vs truth {want:.2}"
                );
            };
            bounded("cpu side", cpu_us(fit, &op), cpu_us(&truth, &op));
            bounded("gpu side", gpu_us(fit, &op), gpu_us(&truth, &op));
            let c1 = (op.cout() / 3).max(4);
            if c1 < op.cout() {
                let total = |spec: &SocSpec| {
                    spec.sync.overhead_us(mech, op.kind())
                        + cpu_us(spec, &op.with_cout(c1))
                            .max(gpu_us(spec, &op.with_cout(op.cout() - c1)))
                };
                bounded("coexec total", total(fit), total(&truth));
            }
        }
    }
}

/// Property: the packed SoA forest is a faithful re-encoding of the
/// Node-enum trees. For random regression problems, the packed walker
/// ([`Gbdt::predict`], which delegates to it) agrees with the enum
/// reference ([`Gbdt::predict_unpacked`]) on essentially every row —
/// thresholds are quantized f64 -> f32, so only a feature value inside
/// the ~2^-24 relative rounding gap of a split midpoint may legally take
/// the other branch — and the tree-major batched walk over a flat
/// row-major matrix is *bit-identical* to the single-row packed walk.
#[test]
fn prop_packed_forest_matches_enum_reference() {
    let mut rng = SplitMix64::new(21);
    for case in 0..8 {
        let n = rng.gen_range(80, 300);
        let d = rng.gen_range(2, 6);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.next_f64() * 200.0 - 100.0).collect())
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| {
                r.iter().enumerate().map(|(j, v)| (j as f64 + 1.0) * v).sum::<f64>().abs() + 1.0
            })
            .collect();
        let params = GbdtParams { n_estimators: 40, ..Default::default() };
        let m = Gbdt::fit(&rows, &y, &params);
        assert!(m.packed().n_trees() > 0, "case {case}: empty packed forest");
        assert!(m.packed().n_nodes() >= m.packed().n_trees(), "case {case}: node pool too small");

        // packed vs enum reference, row by row
        let mut flips = 0usize;
        for r in &rows {
            let p = m.predict(r);
            let u = m.predict_unpacked(r);
            assert!(p.is_finite() && u.is_finite(), "case {case}: non-finite prediction");
            if (p - u).abs() / u.abs().max(1e-12) > 1e-6 {
                flips += 1;
            }
        }
        assert!(
            flips * 100 <= n,
            "case {case}: {flips}/{n} rows diverged beyond f32-threshold quantization"
        );

        // the batched tree-major walk is bit-identical to single-row packed
        let flat: Vec<f64> = rows.iter().flatten().copied().collect();
        let batch = m.packed().predict_batch(&flat, n);
        assert_eq!(batch.len(), n);
        for (i, r) in rows.iter().enumerate() {
            assert!(
                batch[i] == m.packed().predict(r),
                "case {case} row {i}: batched walk not bit-identical to single-row"
            );
        }
        // and the model-level batch entry points agree with themselves
        let via_model = m.predict_batch(&rows);
        let mut via_into = Vec::new();
        m.predict_batch_into(&flat, n, &mut via_into);
        assert_eq!(via_model, batch, "case {case}: Gbdt::predict_batch diverged");
        assert_eq!(via_into, batch, "case {case}: Gbdt::predict_batch_into diverged");
    }
}

/// Property: the histogram-subtraction fast-path trainer ([`Gbdt::fit`])
/// is equivalent to the exact-scan reference trainer
/// ([`Gbdt::fit_reference`]) on random regression problems: same base,
/// same number of trees, *identical* tree structure node for node
/// (features, bin thresholds, leaf values — the ambiguity-triggered
/// exact rebuilds must catch every case where subtraction error could
/// flip a split decision), bit-equal predictions, and agreeing argmins
/// over a candidate sweep (the quantity the planner actually consumes).
#[test]
fn prop_fast_trainer_matches_reference() {
    let mut rng = SplitMix64::new(29);
    for case in 0..10 {
        let n = rng.gen_range(60, 500);
        let d = rng.gen_range(1, 7);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.next_f64() * 200.0 - 100.0).collect())
            .collect();
        // nonlinear target with interactions + noise so trees go deep and
        // sibling histograms genuinely differ in size
        let y: Vec<f64> = rows
            .iter()
            .map(|r| {
                let s: f64 = r.iter().enumerate().map(|(j, v)| (j as f64 + 1.0) * v).sum();
                s.abs() + 10.0 * (r[0] * 0.05).sin() + rng.next_f64()
            })
            .collect();
        let params = GbdtParams {
            n_estimators: rng.gen_range(10, 50),
            max_depth: rng.gen_range(3, 8),
            max_leaves: rng.gen_range(4, 31),
            min_samples_leaf: rng.gen_range(2, 6),
            subsample: 0.6 + 0.4 * rng.next_f64(),
            feature_subsample: 0.5 + 0.5 * rng.next_f64(),
            seed: 100 + case as u64,
            ..Default::default()
        };
        let fast = Gbdt::fit(&rows, &y, &params);
        let refr = Gbdt::fit_reference(&rows, &y, &params);
        assert_eq!(fast.base, refr.base, "case {case}: base diverged");
        assert_eq!(fast.trees.len(), refr.trees.len(), "case {case}: tree count diverged");
        for (ti, (a, b)) in fast.trees.iter().zip(&refr.trees).enumerate() {
            assert_eq!(a.nodes, b.nodes, "case {case} tree {ti}: structure diverged");
            for (j, (ga, gb)) in a.feature_gain.iter().zip(&b.feature_gain).enumerate() {
                assert!(
                    (ga - gb).abs() <= 1e-6 * gb.abs().max(1.0),
                    "case {case} tree {ti} feature {j}: gain {ga} vs {gb}"
                );
            }
        }
        // identical nodes => identical packed forests => bit-equal output
        for r in rows.iter().take(60) {
            assert!(
                fast.predict(r) == refr.predict(r),
                "case {case}: fast and reference predictions not bit-equal"
            );
        }
        // the serving-relevant property: sweeping a candidate set (the
        // planner's argmin over strategies) picks the same winner
        let cands: Vec<Vec<f64>> = (0..64)
            .map(|_| (0..d).map(|_| rng.next_f64() * 200.0 - 100.0).collect())
            .collect();
        let argmin = |m: &Gbdt| {
            cands
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| m.predict(a).partial_cmp(&m.predict(b)).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        assert_eq!(argmin(&fast), argmin(&refr), "case {case}: candidate argmin diverged");
    }
}

/// Property: measurement noise is unbiased (mean factor ~1) and
/// deterministic per trial key.
#[test]
fn prop_noise_unbiased() {
    let device = Device::pixel4();
    let op = OpConfig::Linear(LinearConfig::vit_fc1());
    let model = device.cpu_model_us(&op, ClusterId::Prime, 1);
    let mean_measured = device.measure_mean(
        &op,
        mobile_coexec::device::Processor::Cpu(1),
        400,
    );
    let rel = (mean_measured / model - 1.0).abs();
    assert!(rel < 0.03, "noise bias {rel:.4}");
}
