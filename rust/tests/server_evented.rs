//! Front-end behavior tests for the evented serving loop: pipelining,
//! slow/partial writers, framing errors mid-pipeline, the connection
//! bound, queue-honest telemetry, and the Nagle latency regression.
//!
//! `server_protocol.rs` pins the protocol semantics (reply bytes, cache
//! coherence, shedding); this file pins the *transport* semantics the
//! evented rewrite introduced. Timing is only asserted where the property
//! itself is about time (queue-inclusive latency, the Nagle floor), and
//! always with wide margins.

use mobile_coexec::device::Device;
use mobile_coexec::server::{Server, ServerConfig, ServerState};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Arc, OnceLock};
use std::time::{Duration, Instant};

/// Shared default-config server (lazy state: nothing trains until a PLAN
/// arrives). Each test talks over its own connections.
fn shared() -> SocketAddr {
    static ADDR: OnceLock<SocketAddr> = OnceLock::new();
    *ADDR.get_or_init(|| {
        let state = Arc::new(ServerState::new_lazy(Device::pixel5(), 400, 7));
        Server::new(state, ServerConfig::default())
            .spawn_ephemeral()
            .expect("spawn server")
    })
}

/// Raw connection with a wide read timeout: a starvation or lost-reply bug
/// fails the test instead of hanging the suite. (Wide because a cold PLAN
/// on the lazy shared state trains a planner inside the request.)
fn connect(addr: &SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .expect("read timeout");
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    (stream, reader)
}

fn read_reply(reader: &mut BufReader<TcpStream>) -> String {
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("read reply");
    reply.trim_end_matches('\n').to_string()
}

#[test]
fn pipelined_requests_get_ordered_replies() {
    let addr = shared();
    let (mut stream, mut reader) = connect(&addr);

    // distinguishable replies so an out-of-order or dropped reply is
    // visible; DEVICE goes through the worker pool, PING stays on the
    // event loop, so the sequence also pins fast/slow interleaving
    let devices = ["pixel4", "moto2022", "oneplus11", "pixel5"];
    let mut batch = String::new();
    let mut expected = Vec::new();
    for round in 0..8 {
        let dev = devices[round % devices.len()];
        batch.push_str("PING\n");
        expected.push("OK pong".to_string());
        batch.push_str(&format!("DEVICE {dev}\n"));
        expected.push(format!("OK device {dev}"));
    }
    // all 16 requests written before the first reply is read
    stream.write_all(batch.as_bytes()).expect("write pipeline");
    for (i, want) in expected.iter().enumerate() {
        let got = read_reply(&mut reader);
        assert_eq!(&got, want, "reply {i} out of order or wrong");
    }
}

#[test]
fn partial_line_writer_does_not_starve_other_connections() {
    let addr = shared();
    // slowloris: connection A sends an incomplete line and stalls
    let (mut slow, mut slow_reader) = connect(&addr);
    slow.write_all(b"PIN").expect("partial write");

    // ...while B (connected after A) gets served normally
    let (mut other, mut other_reader) = connect(&addr);
    for _ in 0..3 {
        other.write_all(b"PING\n").expect("write");
        assert_eq!(read_reply(&mut other_reader), "OK pong");
    }

    // A's line completes whenever the bytes finally arrive
    slow.write_all(b"G\n").expect("finish line");
    assert_eq!(read_reply(&mut slow_reader), "OK pong");
}

#[test]
fn invalid_utf8_mid_pipeline_fails_one_request_only() {
    let addr = shared();
    let (mut stream, mut reader) = connect(&addr);
    stream
        .write_all(b"PING\n\xff\xfe\nPING\n")
        .expect("write pipeline");
    assert_eq!(read_reply(&mut reader), "OK pong");
    assert_eq!(read_reply(&mut reader), "ERR invalid utf-8");
    assert_eq!(read_reply(&mut reader), "OK pong");
}

#[test]
fn overlong_line_mid_pipeline_replies_then_hangs_up() {
    let addr = shared();
    let (mut stream, mut reader) = connect(&addr);
    // a valid request, then an unterminated line past the framing limit
    stream.write_all(b"PING\n").expect("write");
    stream.write_all(&vec![b'a'; 70_000]).expect("write flood");
    assert_eq!(read_reply(&mut reader), "OK pong");
    assert_eq!(read_reply(&mut reader), "ERR line too long");
    // documented contract: the server hangs up after the error
    let mut rest = String::new();
    let n = reader.read_line(&mut rest).expect("read eof");
    assert_eq!((n, rest.as_str()), (0, ""), "expected EOF after hang-up");
}

#[test]
fn connection_flood_is_bounded_and_recovers() {
    let state = Arc::new(ServerState::new_lazy(Device::pixel4(), 400, 7));
    let mut server = Server::new(state, ServerConfig::default());
    server.max_conns = 2;
    let addr = server.spawn_ephemeral().expect("spawn server");

    let (mut a, mut a_reader) = connect(&addr);
    let (mut b, mut b_reader) = connect(&addr);
    // both admitted (a reply proves the server accepted the connection)
    a.write_all(b"PING\n").expect("write");
    assert_eq!(read_reply(&mut a_reader), "OK pong");
    b.write_all(b"PING\n").expect("write");
    assert_eq!(read_reply(&mut b_reader), "OK pong");

    // one past the bound: exactly `ERR busy (connection limit)`, then EOF
    let (_c, mut c_reader) = connect(&addr);
    assert_eq!(read_reply(&mut c_reader), "ERR busy (connection limit)");
    let mut rest = Vec::new();
    c_reader.read_to_end(&mut rest).expect("read eof");
    assert!(rest.is_empty(), "no bytes after the shed reply");

    // the admitted connections are unaffected by the shed one
    a.write_all(b"PING\n").expect("write");
    assert_eq!(read_reply(&mut a_reader), "OK pong");
    b.write_all(b"PING\n").expect("write");
    assert_eq!(read_reply(&mut b_reader), "OK pong");

    // closing an admitted connection frees its slot (the loop has to
    // observe the EOF first, hence the bounded retry)
    drop(a);
    drop(a_reader);
    let mut admitted = false;
    for _ in 0..100 {
        let (mut d, mut d_reader) = connect(&addr);
        d.write_all(b"PING\n").expect("write");
        if read_reply(&mut d_reader) == "OK pong" {
            admitted = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(admitted, "slot never freed after a connection closed");
}

#[test]
fn stats_latency_includes_queue_wait() {
    let state = Arc::new(ServerState::new_lazy(Device::pixel4(), 400, 7));
    let server = Server::new(state.clone(), ServerConfig { workers: 1, queue_cap: 8 });
    let addr = server.spawn_ephemeral().expect("spawn server");

    // occupy the single worker so the next request sits in the queue
    let (started_tx, started_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    server
        .pool
        .try_submit(Box::new(move || {
            started_tx.send(()).unwrap();
            release_rx.recv().unwrap();
        }))
        .expect("submit blocker");
    started_rx.recv().expect("blocker running");

    // DEVICE rides the pool (slow path) but is itself microseconds-cheap:
    // any latency it reports is queue wait
    let (mut stream, mut reader) = connect(&addr);
    stream.write_all(b"DEVICE pixel4\n").expect("write");
    std::thread::sleep(Duration::from_millis(200));
    release_tx.send(()).expect("release blocker");
    assert_eq!(read_reply(&mut reader), "OK device pixel4");

    let snap = state.metrics.endpoint("device").latency.snapshot();
    assert_eq!(snap.count, 1);
    assert!(
        snap.p50_us >= 100_000.0,
        "latency must include the ~200ms queue wait, got p50={}us",
        snap.p50_us
    );
}

#[test]
fn warm_round_trips_avoid_the_nagle_stall() {
    let addr = shared();
    let (mut stream, mut reader) = connect(&addr);
    // cold request trains the planner + fills the cache; not measured
    stream.write_all(b"PLAN linear 8 64 128 1\n").expect("write");
    assert!(read_reply(&mut reader).starts_with("OK "));

    let n = 100;
    let mut lat_us: Vec<f64> = (0..n)
        .map(|_| {
            let t = Instant::now();
            stream.write_all(b"PLAN linear 8 64 128 1\n").expect("write");
            let reply = read_reply(&mut reader);
            assert!(reply.starts_with("OK "), "{reply}");
            t.elapsed().as_secs_f64() * 1e6
        })
        .collect();
    lat_us.sort_by(|x, y| x.total_cmp(y));
    // regression gate: a single-write NODELAY reply completes in the µs
    // range; the old two-write no-NODELAY path stalled ~40ms per reply
    // behind Nagle + delayed ACK. 10ms of headroom absorbs CI noise.
    let median = lat_us[n / 2];
    assert!(
        median < 10_000.0,
        "warm round-trip median {median:.0}us suggests the Nagle stall is back"
    );
}
