//! Loopback tests for the observability layer: the `TRACE` / `EXPLAIN` /
//! `METRICS` verbs, the appended `STATS` fields, and their wire contracts.
//!
//! Two servers: a shared one (planner training is the expensive part; pay
//! it once per binary) for the round-trip suites, and a dedicated one for
//! the assertions that need exact state — error paths must mutate
//! nothing, and the slow log must contain exactly the requests this test
//! issued. Tests on the shared server use unique op shapes and
//! "contains at least" assertions so they tolerate each other.

use mobile_coexec::device::Device;
use mobile_coexec::server::{Server, ServerConfig, ServerState};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, OnceLock};

fn shared() -> (&'static Arc<ServerState>, SocketAddr) {
    static STATE: OnceLock<Arc<ServerState>> = OnceLock::new();
    static ADDR: OnceLock<SocketAddr> = OnceLock::new();
    let state = STATE.get_or_init(|| Arc::new(ServerState::new(Device::pixel5(), 800, 7)));
    let addr = *ADDR.get_or_init(|| {
        Server::new(state.clone(), ServerConfig::default())
            .spawn_ephemeral()
            .expect("spawn server")
    });
    (state, addr)
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Self { stream, reader }
    }

    fn request(&mut self, line: &str) -> String {
        self.stream.write_all(line.as_bytes()).expect("write");
        self.stream.write_all(b"\n").expect("write nl");
        self.read_line()
    }

    fn read_line(&mut self) -> String {
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("read");
        reply.trim_end().to_string()
    }

    /// Send a `TRACE` line; return (header, `TR` lines) — the header's
    /// `n=<k>` frames how many lines follow.
    fn request_trace(&mut self, line: &str) -> (String, Vec<String>) {
        let header = self.request(line);
        let n: usize = kv(&header, "n").parse().expect("trace count");
        (header.clone(), (0..n).map(|_| self.read_line()).collect())
    }

    /// Send `METRICS`; return the exposition lines (the header's
    /// `lines=<k>` frames how many follow).
    fn request_metrics(&mut self) -> Vec<String> {
        let header = self.request("METRICS");
        assert!(header.starts_with("OK metrics lines="), "{header}");
        let n: usize = kv(&header, "lines").parse().expect("metrics count");
        (0..n).map(|_| self.read_line()).collect()
    }
}

fn kv_fields(reply: &str) -> Vec<(&str, &str)> {
    reply
        .split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .collect()
}

fn kv<'a>(reply: &'a str, key: &str) -> &'a str {
    kv_fields(reply)
        .into_iter()
        .find(|(k, _)| *k == key)
        .unwrap_or_else(|| panic!("missing {key}= in {reply}"))
        .1
}

/// The free-text `line=` field (last on a `TR` line because it contains
/// spaces).
fn trace_line_field(tr: &str) -> &str {
    let at = tr.find(" line=").unwrap_or_else(|| panic!("no line= in {tr}"));
    &tr[at + " line=".len()..]
}

// ---------------------------------------------------------------- TRACE --

#[test]
fn trace_verb_returns_spans_for_slow_and_fast_paths() {
    let (_, addr) = shared();
    let mut c = Client::connect(&addr);

    // cold plan: slow path -> TLS trace with queue_wait/parse/cache spans
    let cold = c.request("PLAN linear 77 768 3072 3");
    assert!(cold.starts_with("OK "), "{cold}");
    // same line again: warm now, served on the loop -> two-span trace
    let warm = c.request("PLAN linear 77 768 3072 3");
    assert_eq!(warm, cold);

    let (header, lines) = c.request_trace("TRACE last 64");
    assert!(header.starts_with("OK n="), "{header}");
    let window: usize = kv(&header, "window").parse().unwrap();
    assert!(window >= 1, "{header}");
    let submitted: u64 = kv(&header, "submitted").parse().unwrap();
    assert!(submitted >= 2, "{header}");
    assert_eq!(lines.len(), kv(&header, "n").parse::<usize>().unwrap());
    assert!(!lines.is_empty(), "no traces retained: {header}");
    for tr in &lines {
        assert!(tr.starts_with("TR seq="), "{tr}");
        kv(tr, "seq").parse::<u64>().unwrap();
        kv(tr, "total_us").parse::<f64>().unwrap();
        assert!(!kv(tr, "verb").is_empty(), "{tr}");
    }
    // newest-first ordering by sequence number
    let seqs: Vec<u64> = lines.iter().map(|t| kv(t, "seq").parse().unwrap()).collect();
    assert!(seqs.windows(2).all(|w| w[0] > w[1]), "not newest-first: {seqs:?}");

    let ours: Vec<&String> = lines
        .iter()
        .filter(|t| trace_line_field(t) == "PLAN linear 77 768 3072 3")
        .collect();
    assert!(ours.len() >= 2, "both paths must leave traces: {lines:?}");
    let spans_of = |tr: &str| kv_fields(tr).into_iter().find(|(k, _)| *k == "spans").unwrap().1;
    // the slow-path (older, smaller seq) trace saw the TLS span plumbing...
    let slow_path = ours.last().unwrap();
    assert_eq!(kv(slow_path, "verb"), "plan", "{slow_path}");
    assert!(spans_of(slow_path).contains("queue_wait"), "{slow_path}");
    assert!(spans_of(slow_path).contains("cache"), "{slow_path}");
    assert!(spans_of(slow_path).contains("parse"), "{slow_path}");
    // ...the fast-path one was assembled on the loop: probe + write
    let fast_path = ours.first().unwrap();
    assert!(spans_of(fast_path).contains("probe"), "{fast_path}");
    assert!(spans_of(fast_path).contains("write"), "{fast_path}");
}

// -------------------------------------------------------------- EXPLAIN --

#[test]
fn explain_reports_the_search_and_agrees_with_plan() {
    let (_, addr) = shared();
    let mut c = Client::connect(&addr);

    let plan = c.request("PLAN linear 78 768 3072 3");
    let toks: Vec<&str> = plan.split_whitespace().collect();
    let ex = c.request("EXPLAIN linear 78 768 3072 3");
    assert!(ex.starts_with("OK explain "), "{ex}");

    // top1 is the winning plan, byte-for-byte the strategy PLAN returned
    let top1: Vec<&str> = kv(&ex, "top1").split(':').collect();
    assert_eq!(top1.len(), 8, "{ex}");
    assert_eq!(top1[0], format!("{}/{}", toks[1], toks[2]), "split differs: {ex} vs {plan}");
    assert_eq!(top1[1], kv(&plan, "cluster"), "{ex}");
    assert_eq!(top1[2], kv(&plan, "threads"), "{ex}");
    assert_eq!(top1[3], kv(&plan, "mech"), "{ex}");
    assert_eq!(top1[4], kv(&plan, "impl"), "{ex}");
    assert_eq!(top1[7], toks[3], "predicted total differs: {ex} vs {plan}");

    // a fully pinned request searches one strategy point
    assert_eq!(kv(&ex, "impls"), "1/1", "{ex}");
    assert_eq!(kv(&ex, "points").parse::<usize>().unwrap(), 1, "{ex}");
    assert!(kv(&ex, "eval").parse::<u64>().unwrap() > 0, "{ex}");
    assert!(kv(&ex, "splits").parse::<usize>().unwrap() > 0, "{ex}");
    assert_eq!(kv(&ex, "margin_pct"), "0.00", "single point has no runner-up: {ex}");

    // an auto request searches a real grid and reports its win margin
    let auto = c.request("EXPLAIN linear 78 768 3072 auto");
    assert!(auto.starts_with("OK explain "), "{auto}");
    assert!(kv(&auto, "points").parse::<usize>().unwrap() > 1, "{auto}");
    assert!(kv(&auto, "placements").parse::<usize>().unwrap() > 1, "{auto}");
    assert!(kv(&auto, "margin_pct").parse::<f64>().unwrap() >= 0.0, "{auto}");
    // top strategies are in ascending predicted-total order
    let t = |k: &str| -> Option<f64> {
        kv_fields(&auto)
            .into_iter()
            .find(|(key, _)| *key == k)
            .map(|(_, v)| v.split(':').last().unwrap().parse().unwrap())
    };
    let (t1, t2) = (t("top1").unwrap(), t("top2").unwrap());
    assert!(t1 <= t2, "top1 must beat top2: {auto}");
    if let Some(t3) = t("top3") {
        assert!(t2 <= t3, "top2 must beat top3: {auto}");
    }
}

// -------------------------------------------------------------- METRICS --

#[test]
fn metrics_exposes_prometheus_text_format() {
    let (_, addr) = shared();
    let mut c = Client::connect(&addr);

    // drive at least one RUN so per-device residuals exist
    let run = c.request("RUN linear 79 768 3072 3");
    assert!(run.starts_with("OK "), "{run}");

    let lines = c.request_metrics();
    assert!(!lines.is_empty());
    for line in &lines {
        if line.starts_with('#') {
            assert!(line.starts_with("# TYPE coexec_"), "{line}");
            continue;
        }
        // every sample line is `name[{labels}] value`
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line}"));
        assert!(name.starts_with("coexec_"), "{line}");
        value.parse::<f64>().unwrap_or_else(|_| panic!("bad value in {line}"));
    }
    let sample = |prefix: &str| -> f64 {
        lines
            .iter()
            .find(|l| l.starts_with(prefix))
            .unwrap_or_else(|| panic!("missing {prefix} in {lines:?}"))
            .rsplit_once(' ')
            .unwrap()
            .1
            .parse()
            .unwrap()
    };
    assert!(sample("coexec_requests_total{verb=\"plan\"}") >= 0.0);
    assert!(sample("coexec_requests_total{verb=\"metrics\"}") >= 1.0);
    assert!(sample("coexec_run_residual_count{device=\"pixel5\"}") >= 1.0);
    assert!(sample("coexec_run_residual_mean_abs_pct{device=\"pixel5\"}") >= 0.0);
    assert!(sample("coexec_plan_cache_entries") >= 1.0);
    assert!(sample("coexec_connections_active") >= 1.0);
    assert!(sample("coexec_traces_submitted_total") >= 1.0);
    sample("coexec_queue_depth");
    sample("coexec_queue_peak");
    sample("coexec_shed_total");
    assert!(
        lines.iter().any(|l| l.starts_with("coexec_latency_us{verb=\"run\",quantile=\"0.99\"}")),
        "p99 summary missing: {lines:?}"
    );
}

// ---------------------------------------------------------------- STATS --

#[test]
fn stats_fields_keep_positions_with_new_fields_appended() {
    let (_, addr) = shared();
    let mut c = Client::connect(&addr);
    // a RUN guarantees the appended per-device residual block exists
    let run = c.request("RUN linear 80 768 3072 3");
    assert!(run.starts_with("OK "), "{run}");

    let stats = c.request("STATS");
    let body = stats.strip_prefix("OK ").unwrap();
    let keys: Vec<&str> = body
        .split_whitespace()
        .map(|tok| tok.split_once('=').expect("key=value").0)
        .collect();

    // the pre-observability prefix, frozen byte-position by byte-position:
    // cache counters, 13 per-verb blocks, the impl breakdown, train costs
    let mut expect: Vec<String> =
        ["hits", "misses", "entries", "evictions", "expired"].map(String::from).to_vec();
    let legacy_verbs = [
        "ping", "plan", "plan.hit", "plan.miss", "plan_batch", "run", "device", "calibrate",
        "fit", "plan_model", "flush", "stats", "other",
    ];
    for verb in legacy_verbs {
        for field in ["req", "err", "p50_us", "p95_us"] {
            expect.push(format!("{verb}.{field}"));
        }
    }
    for imp in ["default", "direct", "winograd", "tiled_4x4"] {
        expect.push(format!("plan.impl.{imp}"));
    }
    expect.push("train.count".into());
    expect.push("train.us".into());
    assert!(keys.len() > expect.len(), "appended fields missing: {stats}");
    assert_eq!(&keys[..expect.len()], &expect[..], "legacy field positions moved");

    // everything after train.us is append-only, in documented order:
    // new-verb blocks, per-endpoint p99/max, live gauges, residuals
    let mut rest = keys[expect.len()..].iter();
    for verb in ["trace", "explain", "metrics"] {
        for field in ["req", "err", "p50_us", "p95_us"] {
            assert_eq!(rest.next().copied(), Some(format!("{verb}.{field}").as_str()), "{stats}");
        }
    }
    let all_verbs = legacy_verbs.iter().copied().chain(["trace", "explain", "metrics"]);
    for verb in all_verbs {
        for field in ["p99_us", "max_us"] {
            assert_eq!(rest.next().copied(), Some(format!("{verb}.{field}").as_str()), "{stats}");
        }
    }
    for gauge in ["conns.active", "conns.peak", "queue.depth", "queue.peak", "shed"] {
        assert_eq!(rest.next().copied(), Some(gauge), "{stats}");
    }
    for field in ["n", "mean_pct", "max_pct", "bias_pct"] {
        assert_eq!(rest.next().copied(), Some(format!("resid.pixel5.{field}").as_str()), "{stats}");
    }

    // live-gauge sanity: this connection is open, nothing was shed
    assert!(kv(&stats, "conns.active").parse::<u64>().unwrap() >= 1, "{stats}");
    assert!(
        kv(&stats, "conns.peak").parse::<u64>().unwrap()
            >= kv(&stats, "conns.active").parse::<u64>().unwrap(),
        "{stats}"
    );
    kv(&stats, "queue.depth").parse::<u64>().unwrap();
    kv(&stats, "queue.peak").parse::<u64>().unwrap();
    kv(&stats, "shed").parse::<u64>().unwrap();
    assert!(kv(&stats, "resid.pixel5.n").parse::<u64>().unwrap() >= 1, "{stats}");
    // histogram-backed percentiles: p50 <= p95 <= p99 <= max for a verb
    // with traffic
    let p = |k: &str| kv(&stats, k).parse::<f64>().unwrap();
    assert!(p("run.p50_us") <= p("run.p95_us"), "{stats}");
    assert!(p("run.p95_us") <= p("run.p99_us"), "{stats}");
    assert!(p("run.p99_us") <= p("run.max_us") * 1.05, "{stats}");
}

// ------------------------------------------- dedicated-server contracts --

/// Error paths must mutate nothing, and the slow log must converge on
/// exactly the slow requests — both need a server no other test touches.
#[test]
fn err_paths_mutate_nothing_and_slow_log_retains_slow_requests() {
    let state = Arc::new(ServerState::new(Device::pixel5(), 800, 7));
    let addr = Server::new(state.clone(), ServerConfig::default())
        .spawn_ephemeral()
        .expect("spawn server");
    let mut c = Client::connect(&addr);

    // -- error paths, on a virgin state ------------------------------------
    const TRACE_USAGE: &str = "ERR bad request (expected: TRACE [slow|last] [n])";
    assert_eq!(c.request("TRACE bogus 3"), TRACE_USAGE);
    assert_eq!(c.request("TRACE last 1 2"), TRACE_USAGE);
    for bad in ["TRACE 0", "TRACE last 0", "TRACE 65", "TRACE last three"] {
        assert_eq!(c.request(bad), "ERR bad trace count (1..=64)", "{bad}");
    }
    assert_eq!(c.request("METRICS now"), "ERR bad request (expected: METRICS)");
    assert_eq!(c.request("EXPLAIN"), "ERR bad request (expected: EXPLAIN <op-spec>)");
    // malformed op-specs fail exactly like PLAN's (same parser)
    let plan_err = c.request("PLAN linear 1 2");
    assert!(plan_err.starts_with("ERR bad op spec"), "{plan_err}");
    assert_eq!(c.request("EXPLAIN linear 1 2"), plan_err);
    assert_eq!(c.request("EXPLAIN bogus 1 2 3 4"), c.request("PLAN bogus 1 2 3 4"));
    assert_eq!(state.cache.len(), 0, "an error path populated the cache");
    assert_eq!(state.trace.slow_len(), 0, "slow log armed before a threshold was set");

    // a successful EXPLAIN reports the search without memoizing it
    let ex = c.request("EXPLAIN linear 40 256 512 2");
    assert!(ex.starts_with("OK explain "), "{ex}");
    assert_eq!(state.cache.len(), 0, "EXPLAIN must never populate the plan cache");

    // -- slow log ----------------------------------------------------------
    // 1us threshold: every traced request qualifies, so the log must hold
    // exactly the three cold PLANs by the time TRACE builds its reply
    // (a TRACE's own trace is submitted after its reply).
    state.trace.set_slow_us(1);
    for l in [41, 42, 43] {
        let r = c.request(&format!("PLAN linear {l} 256 512 2"));
        assert!(r.starts_with("OK "), "{r}");
    }
    assert_eq!(state.cache.len(), 3);
    let (header, lines) = c.request_trace("TRACE slow 64");
    assert_eq!(kv(&header, "slow_us"), "1", "{header}");
    assert_eq!(kv(&header, "slow_log"), "3", "{header}");
    let plans: Vec<&String> =
        lines.iter().filter(|t| trace_line_field(t).starts_with("PLAN linear 4")).collect();
    assert_eq!(plans.len(), 3, "all three cold plans must be retained: {lines:?}");
    // slowest-first ordering by total time
    let totals: Vec<f64> = lines.iter().map(|t| kv(t, "total_us").parse().unwrap()).collect();
    assert!(totals.windows(2).all(|w| w[0] >= w[1]), "not slowest-first: {totals:?}");
}
