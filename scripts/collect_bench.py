#!/usr/bin/env python3
"""Collect `BENCH ...` lines into a consolidated per-PR trajectory JSON.

The benchutil-based benches (plain `main()`s under rust/benches/) print one
line per measurement in one of two shapes:

    BENCH <name> iters=<n> mean_us=<x> p50_us=<x> p95_us=<x>
    BENCH <name> <metric>=<value>

This script folds every such line from a bench transcript into a single
`{"benches": {name: {metric: value}}}` document, so each PR can commit a
reviewable `BENCH_<n>.json` snapshot and CI can upload a fresh one per run
(see BENCH.md). Anything that is not a BENCH line is ignored, so piping a
whole `cargo bench` transcript through is fine.

Usage:
    collect_bench.py [input|-] [output] [--note TEXT]

Defaults: stdin -> BENCH_6.json. The issue number is parsed from the
output filename (BENCH_<n>.json) when it matches. `--note` records a free
-form provenance string in the document.
"""

import json
import os
import re
import sys

TOKEN = re.compile(r"^([A-Za-z0-9_./-]+)=(-?[0-9.]+(?:[eE][-+]?[0-9]+)?)$")
OUT_ISSUE = re.compile(r"BENCH_(\d+)\.json$")


def collect(lines):
    benches = {}
    for line in lines:
        parts = line.split()
        if len(parts) < 3 or parts[0] != "BENCH":
            continue
        stats = benches.setdefault(parts[1], {})
        for tok in parts[2:]:
            m = TOKEN.match(tok)
            if m:
                stats[m.group(1)] = float(m.group(2))
    return benches


def print_deltas(benches, dst):
    """Per-metric deltas vs the previous PR's committed snapshot.

    The predecessor is `BENCH_<n-1>.json` next to the output file; when it
    does not exist (first PR, or a non-numbered output name) this prints
    nothing. Deltas are informational — the perf gates live in the benches
    themselves — but they make regressions visible in the CI log without
    downloading artifacts.
    """
    m = OUT_ISSUE.search(dst)
    if not m:
        return
    prev_path = os.path.join(
        os.path.dirname(dst) or ".", f"BENCH_{int(m.group(1)) - 1}.json"
    )
    if not os.path.exists(prev_path):
        return
    # deltas are best-effort: an unreadable or malformed predecessor (or
    # one that simply lacks a metric a new PR introduces) must not fail
    # the collection run
    try:
        with open(prev_path) as f:
            prev = json.load(f).get("benches", {})
    except (OSError, ValueError) as e:
        print(f"skipping deltas: cannot read {prev_path}: {e}")
        return
    if not isinstance(prev, dict):
        print(f"skipping deltas: {prev_path} has no benches table")
        return
    print(f"deltas vs {prev_path}:")
    for name in sorted(benches):
        for metric in sorted(benches[name]):
            now = benches[name][metric]
            was = prev.get(name, {}).get(metric) if isinstance(
                prev.get(name, {}), dict
            ) else None
            if not isinstance(was, (int, float)):
                print(f"  {name}.{metric}: {now:.4g} (new)")
            elif was != 0:
                pct = (now - was) / abs(was) * 100.0
                print(f"  {name}.{metric}: {was:.4g} -> {now:.4g} ({pct:+.1f}%)")


def main(argv):
    note = None
    if "--note" in argv:
        i = argv.index("--note")
        if i + 1 >= len(argv):
            sys.exit("--note needs a value")
        note = argv[i + 1]
        del argv[i : i + 2]
    src = argv[1] if len(argv) > 1 else "-"
    dst = argv[2] if len(argv) > 2 else "BENCH_6.json"

    if src == "-":
        lines = sys.stdin.read().splitlines()
    else:
        with open(src) as f:
            lines = f.read().splitlines()

    benches = collect(lines)
    if not benches:
        sys.exit(f"no BENCH lines found in {src!r}")

    doc = {"benches": benches}
    m = OUT_ISSUE.search(dst)
    if m:
        doc["issue"] = int(m.group(1))
    if note:
        doc["note"] = note

    with open(dst, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {dst}: {len(benches)} benches")
    print_deltas(benches, dst)


if __name__ == "__main__":
    main(sys.argv)
