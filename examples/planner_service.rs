//! Planning-as-a-service demo: start the TCP planner server for a device,
//! fire a few client requests at it, print the replies.
//!
//! ```bash
//! cargo run --release --example planner_service
//! ```

use mobile_coexec::device::Device;
use mobile_coexec::server::{request, spawn_ephemeral, ServerState};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    println!("starting planner server for Moto 2022 (training predictors) ...");
    let state = Arc::new(ServerState::new(Device::moto2022(), 2500, 42));
    let addr = spawn_ephemeral(state)?;
    println!("server on {addr}\n");

    for line in [
        "PING",
        "PLAN linear 50 768 3072 3",    // ViT fc1
        "PLAN linear 50 768 3072 3",    // same shape again: cache hit
        "PLAN linear 50 768 3072 auto", // joint (threads, mech) search
        "PLAN linear 50 3072 768 3",    // ViT fc2
        "PLAN conv 64 64 128 192 3 1 3", // Fig 6b conv
        "RUN linear 50 768 3072 3",
        "RUN conv 64 64 128 192 3 1 2",
        "PLAN_MODEL resnet18 3",        // whole model through the cache
        "PLAN_MODEL resnet18 auto",     // per-layer strategy selection
        "PLAN linear oops",
        "FLUSH",                        // calibration changed: drop plans
        "STATS",
    ] {
        let reply = request(&addr, line)?;
        println!("> {line}\n< {reply}");
    }

    // DEVICE is session-scoped, and PLAN_BATCH replies span several
    // lines, so both want a persistent connection.
    println!("\n-- persistent session: switching device, batching --");
    let mut stream = std::net::TcpStream::connect(addr)?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut roundtrip = |line: &str| -> anyhow::Result<String> {
        use std::io::{BufRead, Write};
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        let mut reply = String::new();
        reader.read_line(&mut reply)?;
        println!("> {line}\n< {}", reply.trim());
        Ok(reply.trim().to_string())
    };
    roundtrip("DEVICE pixel5")?;
    roundtrip("PLAN linear 50 768 3072 3")?;
    // a compiler client planning three layers in one round-trip
    let header =
        roundtrip("PLAN_BATCH linear 50 768 3072 auto; linear 50 3072 768 auto; conv 64 64 128 192 3 1 2")?;
    let n: usize = header.strip_prefix("OK n=").unwrap_or("0").parse().unwrap_or(0);
    for _ in 0..n {
        use std::io::BufRead;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        println!("< {}", line.trim());
    }
    Ok(())
}
