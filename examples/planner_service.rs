//! Planning-as-a-service demo: start the TCP planner server for a device,
//! fire a few client requests at it, print the replies.
//!
//! ```bash
//! cargo run --release --example planner_service
//! ```

use mobile_coexec::device::Device;
use mobile_coexec::server::{request, spawn_ephemeral, ServerState};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    println!("starting planner server for Moto 2022 (training predictors) ...");
    let state = Arc::new(ServerState::new(Device::moto2022(), 2500, 42));
    let addr = spawn_ephemeral(state)?;
    println!("server on {addr}\n");

    for line in [
        "PING",
        "PLAN linear 50 768 3072 3",    // ViT fc1
        "PLAN linear 50 768 3072 3",    // same shape again: cache hit
        "PLAN linear 50 3072 768 3",    // ViT fc2
        "PLAN conv 64 64 128 192 3 1 3", // Fig 6b conv
        "RUN linear 50 768 3072 3",
        "RUN conv 64 64 128 192 3 1 2",
        "PLAN_MODEL resnet18 3",        // whole model through the cache
        "PLAN linear oops",
        "STATS",
    ] {
        let reply = request(&addr, line)?;
        println!("> {line}\n< {reply}");
    }

    // DEVICE is session-scoped, so it needs a persistent connection.
    println!("\n-- persistent session: switching device --");
    let mut stream = std::net::TcpStream::connect(addr)?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    for line in ["DEVICE pixel5", "PLAN linear 50 768 3072 3"] {
        use std::io::{BufRead, Write};
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        let mut reply = String::new();
        reader.read_line(&mut reply)?;
        println!("> {line}\n< {}", reply.trim());
    }
    Ok(())
}
