//! Self-calibration demo: profile a built-in phone, fit a fresh spec
//! from its own measurements, and print the per-group residuals.
//!
//! The fit starts from a deliberately *mis-calibrated* base (every
//! constant nudged 25-40% off), so the recovery is real work, not a
//! no-op: the solver has to pull throughput, thread-efficiency,
//! bandwidth, launch, GPU, and sync constants back to the phone's truth
//! from nothing but `(op, placement, observed_us)` records — exactly
//! what the serving layer's `FIT` verb does with an uploaded profiling
//! run.
//!
//! ```bash
//! cargo run --release --example self_calibrate [-- pixel4|pixel5|moto2022|oneplus11]
//! ```

use mobile_coexec::calibration::{fit_spec, SampleSet};
use mobile_coexec::device::{ClusterId, Device, SyncMechanism};
use mobile_coexec::ops::{LinearConfig, OpConfig};

fn main() -> anyhow::Result<()> {
    let which = std::env::args().nth(1).unwrap_or_else(|| "pixel5".into());
    let device = mobile_coexec::server::canonical_device_key(&which)
        .and_then(mobile_coexec::server::device_by_key)
        .unwrap_or_else(|| {
            eprintln!("unknown device {which}");
            std::process::exit(2);
        });

    // a mis-calibrated starting point: the same phone with every fitted
    // constant pushed off by 25-40%
    let mut base = device.spec.clone();
    base.apply_params(&[
        ("cpu.prime.gmacs_per_thread", base.cpu.clusters[0].gmacs_per_thread * 1.35),
        ("cpu.prime.launch_us", base.cpu.clusters[0].launch_us * 0.7),
        ("gpu.macs_per_cu_cycle", base.gpu.macs_per_cu_cycle * 0.75),
        ("gpu.dispatch_us", base.gpu.dispatch_us * 1.4),
        ("sync.polling_linear_us", base.sync.polling_linear_us * 1.6),
        ("sync.event_linear_us", base.sync.event_linear_us * 0.75),
    ])?;

    println!("profiling {} (synthesized measure_* campaign) ...", device.name());
    let samples = SampleSet::synthesize(&device, 12);
    println!("fitting {} samples against the mis-calibrated base ...\n", samples.len());
    let report = fit_spec(&base, &samples)?;
    println!("{}", report.render());

    // the loop closes: the quantity plans minimize — predicted co-exec
    // latency — lands back on the phone's truth
    println!("\npredicted latency, truth vs mis-calibrated vs fitted:");
    println!(
        "{:<28} {:>10} {:>12} {:>10}",
        "op (prime, 2 threads)", "truth_us", "miscal_us", "fitted_us"
    );
    for op in [
        OpConfig::Linear(LinearConfig::vit_fc1()),
        OpConfig::Linear(LinearConfig::new(64, 512, 1024)),
        OpConfig::Linear(LinearConfig::new(2, 16, 24)),
    ] {
        let pred = |d: &Device| {
            let cpu = d.cpu_model_us(&op, ClusterId::Prime, 2);
            let (gpu, _) = d.gpu_model_us(&op);
            let sync = d.sync_overhead_us(SyncMechanism::SvmPolling, op.kind());
            cpu.max(gpu) + sync
        };
        println!(
            "{op:<28} {:>10.1} {:>12.1} {:>10.1}",
            pred(&device),
            pred(&Device::new(base.clone())),
            pred(&Device::new(report.spec.clone())),
        );
    }
    println!(
        "\n{} of {} groups fitted, overall residual {:.2}%",
        report.fitted_groups(),
        report.groups.len(),
        report.overall_resid() * 100.0
    );
    Ok(())
}
