//! END-TO-END DRIVER (serving): load the real AOT-compiled ViT linear
//! layers (JAX + Pallas -> HLO -> PJRT) and serve batched requests through
//! the two-worker co-execution engine, reporting latency and throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example vit_serving
//! ```
//!
//! This is the proof that all three layers compose: the Pallas GEMM kernel
//! (L1) is inside the JAX-lowered artifact (L2), executed by the Rust
//! coordinator (L3) on two PJRT workers that share an output buffer and
//! rendezvous with SVM-style polling. Numerics are verified against the
//! fused reference artifact on every 16th request.

use mobile_coexec::coexec::CoexecEngine;
use mobile_coexec::device::noise::SplitMix64;
use mobile_coexec::device::SyncMechanism;
use mobile_coexec::metrics::percentile;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let (l, cin, cout, c1) = (50usize, 768usize, 3072usize, 592usize);
    let engine = CoexecEngine::with_default_artifacts()?;
    let split = Some(("linear_cpu_c592".to_string(), "linear_gpu_c592".to_string()));

    // fixed weights (the deployed model); fresh activations per request
    let mut rng = SplitMix64::new(2024);
    let mut gen = |n: usize| -> Vec<f32> {
        (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 0.2).collect()
    };
    let w = gen(cin * cout);
    let b = gen(cout);

    println!("serving ViT-Base-32 fc1 (50x768 @ 768x3072, split c1={c1}) over PJRT ...");
    let n_requests = 64;
    let mut latencies = Vec::with_capacity(n_requests);
    let mut verified = 0usize;
    let t_start = Instant::now();
    for req in 0..n_requests {
        let x = gen(l * cin);
        let t0 = Instant::now();
        // weights_key: the deployed weights are immutable, so workers keep
        // their staged literals across requests (EXPERIMENTS.md §Perf)
        let (y, _report) = engine.run_linear_keyed(
            &x,
            &w,
            &b,
            (l, cin, cout),
            c1,
            SyncMechanism::SvmPolling,
            split.clone(),
            Some(1),
        )?;
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
        if req % 16 == 0 {
            let want = engine.run_full_reference("linear_full", &x, &w, &b, (l, cin, cout))?;
            let max_err = y
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            anyhow::ensure!(max_err < 2e-3, "request {req}: max err {max_err}");
            verified += 1;
        }
    }
    let wall_s = t_start.elapsed().as_secs_f64();
    // warm-up skew: drop the first 8 (compile + cache fill)
    let steady = &latencies[8..];
    println!(
        "served {n_requests} requests in {wall_s:.2}s  ({:.1} req/s)",
        n_requests as f64 / wall_s
    );
    println!(
        "steady-state latency: p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms",
        percentile(steady, 50.0).expect("steady window is non-empty"),
        percentile(steady, 95.0).expect("steady window is non-empty"),
        percentile(steady, 99.0).expect("steady window is non-empty")
    );
    println!("numerics verified on {verified} requests (vs fused AOT reference)");
    Ok(())
}
