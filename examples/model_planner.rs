//! Whole-model offline planning (the paper's §5.4 deployment flow): train
//! predictors for a device, plan every layer of ResNet-18 and VGG16 with
//! per-layer auto strategy selection (each layer picks its own channel
//! split, CPU thread count, and sync mechanism), print the decisions, and
//! report the end-to-end speedup.
//!
//! ```bash
//! cargo run --release --example model_planner [pixel4|pixel5|moto2022|oneplus11]
//! ```

use mobile_coexec::device::Device;
use mobile_coexec::models::{self, Layer};
use mobile_coexec::partition::{PlanRequest, Planner};
use mobile_coexec::scheduler::ModelScheduler;

fn main() {
    let device = match std::env::args().nth(1).as_deref() {
        Some("pixel4") => Device::pixel4(),
        Some("moto2022") => Device::moto2022(),
        Some("oneplus11") => Device::oneplus11(),
        _ => Device::pixel5(),
    };
    println!("planning for {} (per-layer auto strategy selection)", device.name());
    println!("training predictors ...");
    let lp = Planner::train_for_kind(&device, "linear", 4000, 42);
    let cp = Planner::train_for_kind(&device, "conv", 4000, 42);
    let sched = ModelScheduler {
        device: &device,
        linear_planner: &lp,
        conv_planner: &cp,
        req: PlanRequest::auto(),
    };

    for model in [models::resnet18(), models::vgg16()] {
        println!("\n=== {} ===", model.name);
        let schedule = sched.plan(&model);
        let mut coexec_layers = 0;
        for (i, ls) in schedule.iter().enumerate() {
            match (&ls.layer, &ls.plan) {
                (Layer::Pool { .. }, _) => {
                    println!("  [{i:2}] pool -> GPU (pinned)");
                }
                (_, Some(plan)) => {
                    let op = ls.layer.op().unwrap();
                    if plan.split.is_coexec() {
                        coexec_layers += 1;
                        println!(
                            "  [{i:2}] {op} -> CPU {:4} | GPU {:4}  ({} thr on {}, {:?}, pred {:.0} us)",
                            plan.split.c_cpu,
                            plan.split.c_gpu,
                            plan.threads,
                            plan.cluster,
                            plan.mech,
                            plan.t_total_us
                        );
                    } else if plan.split.c_cpu > 0 {
                        println!("  [{i:2}] {op} -> CPU only (pred {:.0} us)", plan.t_total_us);
                    } else {
                        println!("  [{i:2}] {op} -> GPU only (pred {:.0} us)", plan.t_total_us);
                    }
                }
                _ => {}
            }
        }
        let r = sched.evaluate(&model);
        println!(
            "  co-executed layers: {coexec_layers}/{}\n  chosen threads: {:?}  mechs: {:?}\n  baseline {:.1} ms -> e2e {:.1} ms  ({:.2}x speedup)",
            schedule.len(),
            r.strategies.threads,
            r.strategies.mechs,
            r.baseline_ms,
            r.e2e_ms,
            r.e2e_speedup()
        );
    }
}
