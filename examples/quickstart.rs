//! Quickstart: plan and evaluate a co-execution strategy for one layer.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Trains latency predictors for the Pixel 5 model (the paper's §5.2
//! offline step), plans the ViT-Base-32 flagship linear layer
//! (50, 768) x (768, 3072), and compares the measured co-execution latency
//! against GPU-only execution — the paper's headline workflow in ~40 lines.

use mobile_coexec::device::{ClusterId, Device, Processor, SyncMechanism};
use mobile_coexec::ops::{LinearConfig, OpConfig};
use mobile_coexec::partition::{grid_search, Planner};

fn main() {
    let device = Device::pixel5();
    println!("device: {}", device.name());

    // 1. Offline: sample a training set, measure it, train augmented
    //    GBDT predictors (paper §3.2 + §5.2).
    println!("training predictors (offline, once per device) ...");
    let planner = Planner::train_for_kind(&device, "linear", 4000, 42);

    // 2. Plan the flagship op: fc1 of ViT-Base-32.
    let op = OpConfig::Linear(LinearConfig::vit_fc1());
    let plan = planner.plan_with_threads(&op, 3);
    println!(
        "plan for {op}: CPU {} channels | GPU {} channels (predicted {:.0} us)",
        plan.split.c_cpu, plan.split.c_gpu, plan.t_total_us
    );

    // 3. Evaluate: measured co-execution vs GPU-only baseline.
    let t_co = planner.measure_plan_us(&op, &plan, 32);
    let t_gpu = device.measure_mean(&op, Processor::Gpu, 32);
    let t_cpu3 = device.measure_mean(&op, Processor::Cpu(3), 32);
    println!("GPU-only:  {t_gpu:.0} us");
    println!("CPU-only (3 threads): {t_cpu3:.0} us");
    println!("co-execution:         {t_co:.0} us  -> {:.2}x speedup", t_gpu / t_co);

    // 4. Sanity: how close is the plan to the measured grid-search oracle?
    let (oracle_split, t_oracle) =
        grid_search(&device, &op, ClusterId::Prime, 3, SyncMechanism::SvmPolling, 16);
    println!(
        "grid-search oracle: CPU {} | GPU {} at {t_oracle:.0} us ({:.2}x) — planner is within {:.1}%",
        oracle_split.c_cpu,
        oracle_split.c_gpu,
        t_gpu / t_oracle,
        (t_co / t_oracle - 1.0) * 100.0
    );
}
