"""L2 correctness: model entry points + AOT lowering round-trip sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rng(seed=0):
    return np.random.default_rng(seed)


def randn(r, *shape):
    return jnp.asarray(r.standard_normal(shape, dtype=np.float32))


def test_linear_entry_matches_ref():
    r = rng(0)
    x, w, b = randn(r, 50, 768), randn(r, 768, 3072), randn(r, 3072)
    (got,) = model.linear(x, w, b)
    np.testing.assert_allclose(got, ref.linear(x, w, b), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("c1", [0, 592, 1536, 3072])
def test_linear_partitioned_entry(c1):
    r = rng(c1 + 1)
    x, w, b = randn(r, 50, 768), randn(r, 768, 3072), randn(r, 3072)
    (got,) = model.linear_partitioned(c1)(x, w, b)
    np.testing.assert_allclose(got, ref.linear(x, w, b), rtol=1e-4, atol=1e-3)


def test_partition_slices_reassemble():
    """cpu-slice ++ gpu-slice == full output — the identity the Rust
    co-execution engine depends on when it merges worker results."""
    r = rng(7)
    c1 = 592
    x, w, b = randn(r, 50, 768), randn(r, 768, 3072), randn(r, 3072)
    (y_cpu,) = model.linear_partition_slice(c1, "cpu")(x, w, b)
    (y_gpu,) = model.linear_partition_slice(c1, "gpu")(x, w, b)
    assert y_cpu.shape == (50, c1) and y_gpu.shape == (50, 3072 - c1)
    got = jnp.concatenate([y_cpu, y_gpu], axis=-1)
    np.testing.assert_allclose(got, ref.linear(x, w, b), rtol=1e-4, atol=1e-3)


def test_conv_slices_reassemble():
    r = rng(8)
    c1 = 64
    x, w = randn(r, 1, 64, 64, 128), randn(r, 3, 3, 128, 192)
    (y_cpu,) = model.conv_partition_slice(c1, "cpu")(x, w)
    (y_gpu,) = model.conv_partition_slice(c1, "gpu")(x, w)
    got = jnp.concatenate([y_cpu, y_gpu], axis=-1)
    np.testing.assert_allclose(got, ref.conv2d(x, w), rtol=2e-4, atol=2e-4)


def test_conv_winograd_entry_matches_direct():
    r = rng(9)
    x, w = randn(r, 1, 64, 64, 128), randn(r, 3, 3, 128, 192)
    (direct,) = model.conv3x3(x, w)
    (wino,) = model.conv3x3_winograd(x, w)
    np.testing.assert_allclose(wino, direct, rtol=5e-3, atol=5e-3)


def test_vit_mlp_block_partition_invariant():
    """The block output must not depend on the split point."""
    r = rng(10)
    x = randn(r, 50, 768)
    w1, b1 = randn(r, 768, 3072), randn(r, 3072)
    w2, b2 = randn(r, 3072, 768), randn(r, 768)
    (y_a,) = model.vit_mlp_block(592)(x, w1, b1, w2, b2)
    (y_b,) = model.vit_mlp_block(3072)(x, w1, b1, w2, b2)
    assert y_a.shape == (50, 768)
    assert bool(jnp.all(jnp.isfinite(y_a)))
    np.testing.assert_allclose(y_a, y_b, rtol=1e-4, atol=1e-4)


# --- AOT lowering -----------------------------------------------------------


def test_lower_linear_to_hlo_text():
    text = aot.lower(model.linear, model.vit_linear_shapes())
    assert text.startswith("HloModule")
    assert "dot(" in text or "dot " in text


def test_lower_partition_slice_to_hlo_text():
    text = aot.lower(
        model.linear_partition_slice(592, "gpu"), model.vit_linear_shapes()
    )
    assert text.startswith("HloModule")
    # the gpu slice contracts 768 x 2480
    assert "2480" in text


def test_build_entries_complete():
    entries = aot.build_entries()
    names = [e[0] for e in entries]
    assert "linear_full" in names
    assert "conv3x3_winograd" in names
    assert "vit_mlp_block_c592" in names
    for c1 in aot.LINEAR_SPLITS:
        assert f"linear_cpu_c{c1}" in names and f"linear_gpu_c{c1}" in names
    assert len(names) == len(set(names)), "duplicate artifact names"
