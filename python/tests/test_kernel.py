"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes (including non-block-aligned ones) and the split
point c1; numpy oracles pin semantics. This is the CORE correctness signal
for the AOT artifacts the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as kconv
from compile.kernels import matmul as kmm
from compile.kernels import ref
from compile.kernels import winograd as kwino

jax.config.update("jax_platform_name", "cpu")


def rng(seed=0):
    return np.random.default_rng(seed)


def randn(r, *shape):
    return jnp.asarray(r.standard_normal(shape, dtype=np.float32))


# --- matmul -----------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [
        (50, 768, 3072),  # flagship ViT linear
        (64, 256, 256),  # block-aligned
        (7, 13, 19),  # nothing aligned
        (1, 1, 1),  # degenerate
        (128, 32, 512),
    ],
)
def test_matmul_matches_ref(m, k, n):
    r = rng(m * 7 + k * 3 + n)
    x, w = randn(r, m, k), randn(r, k, n)
    got = kmm.matmul(x, w)
    want = ref.linear(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_matmul_bias():
    r = rng(1)
    x, w, b = randn(r, 50, 768), randn(r, 768, 512), randn(r, 512)
    np.testing.assert_allclose(
        kmm.matmul(x, w, b), ref.linear(x, w, b), rtol=1e-4, atol=1e-3
    )


def test_matmul_custom_blocks():
    r = rng(2)
    x, w = randn(r, 100, 300), randn(r, 300, 500)
    got = kmm.matmul(x, w, block_m=32, block_n=128)
    np.testing.assert_allclose(got, ref.linear(x, w), rtol=1e-4, atol=1e-3)


def test_matmul_ktiled_matches_ref():
    r = rng(3)
    x, w = randn(r, 40, 1100), randn(r, 1100, 333)
    got = kmm.matmul_ktiled(x, w, block_k=256)
    np.testing.assert_allclose(got, ref.linear(x, w), rtol=1e-4, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 128),
    n=st.integers(1, 320),
    seed=st.integers(0, 2**16),
)
def test_matmul_hypothesis(m, k, n, seed):
    r = rng(seed)
    x, w = randn(r, m, k), randn(r, k, n)
    got = kmm.matmul(x, w, block_m=32, block_n=128)
    np.testing.assert_allclose(got, ref.linear(x, w), rtol=1e-4, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 256),
    c1_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_linear_partition_identity(n, c1_frac, seed):
    """Partitioned output == unpartitioned output for every split (Fig. 4)."""
    r = rng(seed)
    c1 = int(round(c1_frac * n))
    x, w, b = randn(r, 17, 48), randn(r, 48, n), randn(r, n)
    got = kmm.linear_partitioned(x, w, c1, b)
    want = ref.linear(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
    # and the ref partition agrees with the fused ref
    np.testing.assert_allclose(
        ref.linear_partitioned(x, w, c1, b), want, rtol=1e-4, atol=1e-3
    )


# --- conv2d -----------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 3, 5, 7])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv2d_matches_lax(k, stride):
    r = rng(k * 10 + stride)
    x = randn(r, 2, 16, 16, 8)
    w = randn(r, k, k, 8, 24)
    got = kconv.conv2d(x, w, stride=stride, padding="SAME")
    want = ref.conv2d(x, w, stride=stride, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_conv2d_valid_padding():
    r = rng(9)
    x, w = randn(r, 1, 14, 14, 4), randn(r, 3, 3, 4, 6)
    got = kconv.conv2d(x, w, stride=1, padding="VALID")
    want = ref.conv2d(x, w, stride=1, padding="VALID")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_conv2d_fig6b_shape():
    """The paper's Fig. 6b workload: 3x3 conv on (64, 64, 128)."""
    r = rng(11)
    x, w = randn(r, 1, 64, 64, 128), randn(r, 3, 3, 128, 160)
    got = kconv.conv2d(x, w)
    assert got.shape == (1, 64, 64, 160)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(4, 20),
    w_=st.integers(4, 20),
    cin=st.integers(1, 16),
    cout=st.integers(1, 40),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_hypothesis(h, w_, cin, cout, k, stride, seed):
    r = rng(seed)
    x = randn(r, 1, h, w_, cin)
    w = randn(r, k, k, cin, cout)
    got = kconv.conv2d(x, w, stride=stride, padding="SAME", block_m=32, block_n=64)
    want = ref.conv2d(x, w, stride=stride, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(
    cout=st.integers(2, 48),
    c1_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_conv_partition_identity(cout, c1_frac, seed):
    r = rng(seed)
    c1 = int(round(c1_frac * cout))
    x, w = randn(r, 1, 8, 8, 6), randn(r, 3, 3, 6, cout)
    got = kconv.conv2d_partitioned(x, w, c1)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# --- winograd ---------------------------------------------------------------


def test_winograd_matches_direct():
    r = rng(21)
    x, w = randn(r, 1, 16, 16, 8), randn(r, 3, 3, 8, 32)
    got = kwino.winograd_conv3x3(x, w)
    want = ref.conv2d(x, w, stride=1, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_winograd_ref_matches_direct():
    r = rng(22)
    x, w = randn(r, 2, 10, 12, 5), randn(r, 3, 3, 5, 9)
    got = ref.winograd_conv3x3(x, w)
    want = ref.conv2d(x, w, stride=1, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_winograd_fig6b_switch_shape():
    """Cout > 128 is where TFLite switches to winograd (Fig. 6b)."""
    r = rng(23)
    x, w = randn(r, 1, 32, 32, 16), randn(r, 3, 3, 16, 144)
    got = kwino.winograd_conv3x3(x, w)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(
    th=st.integers(2, 8),
    tw=st.integers(2, 8),
    cin=st.integers(1, 12),
    cout=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_winograd_hypothesis(th, tw, cin, cout, seed):
    r = rng(seed)
    x = randn(r, 1, th * 2, tw * 2, cin)
    w = randn(r, 3, 3, cin, cout)
    got = kwino.winograd_conv3x3(x, w)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_transform_domain_gemm():
    r = rng(31)
    v = randn(r, 16, 70, 24)
    u = randn(r, 16, 24, 40)
    got = kwino.transform_domain_gemm(v, u, block_p=32, block_n=32)
    want = jnp.einsum("tpc,tco->tpo", v, u)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# --- misc ref ops -----------------------------------------------------------


def test_maxpool():
    r = rng(41)
    x = randn(r, 1, 8, 8, 3)
    got = ref.maxpool2x2(x)
    assert got.shape == (1, 4, 4, 3)
    xn = np.asarray(x)
    want = xn.reshape(1, 4, 2, 4, 2, 3).max(axis=(2, 4))
    np.testing.assert_allclose(got, want)
