"""Wire-name sync: the serving protocol's kernel-implementation vocabulary
(`impl=` on PLAN/RUN/FIT lines, defined by `ReqImpl::wire()` in
rust/src/device/gpu.rs) must stay in lockstep with the Pallas kernel
variants under python/compile/kernels/.

Pure-stdlib source parsing — no jax import — so this check runs even on a
box without the accelerator stack.
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
GPU_RS = REPO / "rust" / "src" / "device" / "gpu.rs"
KERNELS = REPO / "python" / "compile" / "kernels"

# Which Pallas kernel module implements each forced wire name. `default`
# is the delegate's own heuristic: it has no forced python variant.
WIRE_TO_MODULE = {
    "direct": "conv2d",  # im2col + GEMM, the conv_generic analogue
    "tiled_4x4": "matmul",  # MXU-tiled GEMM (vec4-style tiling)
    "winograd": "winograd",  # F(2x2,3x3) transform-domain GEMM
}


def rust_wire_names():
    """The `ReqImpl::<Variant> => "<wire>"` arms of `ReqImpl::wire()`."""
    src = GPU_RS.read_text()
    names = re.findall(r'ReqImpl::\w+ => "([a-z0-9_]+)"', src)
    assert names, f"no ReqImpl wire arms found in {GPU_RS}"
    return set(names)


def test_rust_wire_vocabulary_is_exactly_the_five_axis_set():
    assert rust_wire_names() == {"default", "direct", "winograd", "tiled_4x4"}


def test_every_forced_wire_name_has_a_pallas_kernel_module():
    forced = rust_wire_names() - {"default"}
    assert forced == set(WIRE_TO_MODULE), (
        "update WIRE_TO_MODULE when the Rust impl axis grows or shrinks"
    )
    for wire, module in WIRE_TO_MODULE.items():
        path = KERNELS / f"{module}.py"
        assert path.is_file(), f"impl={wire} maps to missing kernel {path}"


def test_kernel_package_exports_every_mapped_module():
    init = (KERNELS / "__init__.py").read_text()
    exported = set()
    for line in init.splitlines():
        m = re.match(r"from \. import (.+?)(?:\s*#.*)?$", line.strip())
        if m:
            exported.update(n.strip() for n in m.group(1).split(","))
    for wire, module in WIRE_TO_MODULE.items():
        assert module in exported, (
            f"impl={wire}: kernels/__init__.py must export {module}"
        )
