"""L2: partitioned-operator compute graphs, lowered once by aot.py.

Each entry point is a jax function over concrete example shapes; aot.py
lowers them to HLO text that the Rust runtime (rust/src/runtime/) loads via
PJRT. The flagship shapes are the paper's running examples:

  * ViT-Base-32 MLP linear: X(50, 768) @ W(768, 3072)   (Sections 1, 3)
  * Fig. 6b conv: 3x3, input (64, 64, 128), stride 1
  * a ViT encoder MLP block (linear -> GELU -> linear) to prove multi-op
    graphs with a partitioned hot layer compose into one HLO module.

Every partitioned entry point takes the full weight tensor and a *static*
split point c1 (partition decisions are made offline by the Rust planner —
Section 5.2 of the paper: "partitioning decisions can be made offline ...
as part of the compilation process"), so each (op, split) pair is its own
AOT artifact; the runtime caches one executable per artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import conv2d as kconv
from .kernels import matmul as kmm
from .kernels import winograd as kwino


# --- Linear -----------------------------------------------------------------

def linear(x, w, b):
    """Full linear layer on one device (baseline / exclusive execution)."""
    return (kmm.matmul(x, w, b),)


def linear_partitioned(c1: int):
    """Returns fn(x, w, b) computing the c1-split partitioned linear layer."""

    def fn(x, w, b):
        return (kmm.linear_partitioned(x, w, c1, b),)

    return fn


def linear_partition_slice(c1: int, side: str):
    """One side of the partition as its own artifact.

    The Rust co-execution engine launches the two sides on separate worker
    threads (the simulated "CPU" and "GPU"), so each side must be an
    independently loadable executable. ``side`` selects which weight slice
    this artifact consumes.
    """
    assert side in ("cpu", "gpu")

    def fn(x, w, b):
        if side == "cpu":
            return (kmm.matmul(x, w[:, :c1], b[:c1]),)
        return (kmm.matmul(x, w[:, c1:], b[c1:]),)

    return fn


# --- Conv -------------------------------------------------------------------

def conv3x3(x, w):
    """Fig. 6b conv, direct im2col path (TFLite conv_generic analogue)."""
    return (kconv.conv2d(x, w, stride=1, padding="SAME"),)


def conv3x3_winograd(x, w):
    """Fig. 6b conv on the Winograd fast path (Cout > 128 in TFLite)."""
    return (kwino.winograd_conv3x3(x, w),)


def conv_partitioned(c1: int, stride: int = 1):
    def fn(x, w):
        return (kconv.conv2d_partitioned(x, w, c1, stride=stride, padding="SAME"),)

    return fn


def conv_partition_slice(c1: int, side: str, stride: int = 1):
    assert side in ("cpu", "gpu")

    def fn(x, w):
        ws = w[..., :c1] if side == "cpu" else w[..., c1:]
        return (kconv.conv2d(x, ws, stride=stride, padding="SAME"),)

    return fn


# --- ViT MLP block ----------------------------------------------------------

def vit_mlp_block(c1: int):
    """ViT-Base-32 encoder MLP: LN -> fc1(768->3072, partitioned at c1) ->
    GELU -> fc2(3072->768), residual. The partitioned fc1 is the paper's
    flagship op.
    """

    def fn(x, w1, b1, w2, b2):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        xn = (x - mu) * jax.lax.rsqrt(var + 1e-6)
        h = kmm.linear_partitioned(xn, w1, c1, b1)
        h = jax.nn.gelu(h)
        y = kmm.matmul(h, w2, b2)
        return (x + y,)

    return fn


# --- Example shapes (single source of truth for aot.py and tests) -----------

VIT_L, VIT_CIN, VIT_COUT = 50, 768, 3072
CONV_H = CONV_W = 64
CONV_CIN, CONV_COUT = 128, 192


def vit_linear_shapes():
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((VIT_L, VIT_CIN), f32),
        jax.ShapeDtypeStruct((VIT_CIN, VIT_COUT), f32),
        jax.ShapeDtypeStruct((VIT_COUT,), f32),
    )


def conv_shapes(cout: int = CONV_COUT):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((1, CONV_H, CONV_W, CONV_CIN), f32),
        jax.ShapeDtypeStruct((3, 3, CONV_CIN, cout), f32),
    )


def vit_block_shapes():
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((VIT_L, VIT_CIN), f32),
        jax.ShapeDtypeStruct((VIT_CIN, VIT_COUT), f32),
        jax.ShapeDtypeStruct((VIT_COUT,), f32),
        jax.ShapeDtypeStruct((VIT_COUT, VIT_CIN), f32),
        jax.ShapeDtypeStruct((VIT_CIN,), f32),
    )
