"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles on the PJRT CPU
client. HLO text — NOT ``lowered.compile().serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids that xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Alongside the ``*.hlo.txt`` files we emit ``manifest.json`` describing each
artifact (entry name, file, argument shapes, op metadata such as the split
point c1 and partition side) — the Rust ``runtime::ArtifactRegistry`` is
driven entirely by this manifest.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big literals as
    # `{...}`, which HloModuleProto::from_text_file silently parses as
    # ZEROS (bit us on the Winograd transform matrices — 16x16 constants).
    return comp.as_hlo_text(print_large_constants=True)


def lower(fn, shapes) -> str:
    return to_hlo_text(jax.jit(fn).lower(*shapes))


# The splits shipped as AOT artifacts. 592 is the paper's own best CPU share
# for the flagship ViT linear on OnePlus 11 (Section 3.2: 2480 GPU + 592
# CPU); the others bracket it so the co-execution examples can sweep.
LINEAR_SPLITS = (384, 592, 768, 1024, 1536)
CONV_SPLITS = (48, 64, 96)


def build_entries():
    """(name, fn, shapes, meta) for every artifact."""
    entries = []
    lin_shapes = model.vit_linear_shapes()
    entries.append(
        (
            "linear_full",
            model.linear,
            lin_shapes,
            {
                "op": "linear",
                "l": model.VIT_L,
                "cin": model.VIT_CIN,
                "cout": model.VIT_COUT,
            },
        )
    )
    for c1 in LINEAR_SPLITS:
        meta = {
            "op": "linear",
            "l": model.VIT_L,
            "cin": model.VIT_CIN,
            "cout": model.VIT_COUT,
            "c1": c1,
        }
        entries.append(
            (f"linear_part_c{c1}", model.linear_partitioned(c1), lin_shapes, meta)
        )
        for side in ("cpu", "gpu"):
            entries.append(
                (
                    f"linear_{side}_c{c1}",
                    model.linear_partition_slice(c1, side),
                    lin_shapes,
                    {**meta, "side": side},
                )
            )

    conv_shapes = model.conv_shapes()
    conv_meta = {
        "op": "conv",
        "h": model.CONV_H,
        "w": model.CONV_W,
        "cin": model.CONV_CIN,
        "cout": model.CONV_COUT,
        "k": 3,
        "stride": 1,
    }
    entries.append(("conv3x3_full", model.conv3x3, conv_shapes, conv_meta))
    entries.append(
        (
            "conv3x3_winograd",
            model.conv3x3_winograd,
            conv_shapes,
            {**conv_meta, "impl": "winograd"},
        )
    )
    for c1 in CONV_SPLITS:
        meta = {**conv_meta, "c1": c1}
        entries.append(
            (f"conv3x3_part_c{c1}", model.conv_partitioned(c1), conv_shapes, meta)
        )
        for side in ("cpu", "gpu"):
            entries.append(
                (
                    f"conv3x3_{side}_c{c1}",
                    model.conv_partition_slice(c1, side),
                    conv_shapes,
                    {**meta, "side": side},
                )
            )

    entries.append(
        (
            "vit_mlp_block_c592",
            model.vit_mlp_block(592),
            model.vit_block_shapes(),
            {"op": "vit_mlp_block", "c1": 592},
        )
    )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--out",
        default=None,
        help="also write the first artifact to this path (Makefile stamp)",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    first_path = None
    for name, fn, shapes, meta in build_entries():
        text = lower(fn, shapes)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        if first_path is None:
            first_path = path
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "args": [{"shape": list(s.shape), "dtype": "f32"} for s in shapes],
                "meta": meta,
            }
        )
        print(f"  {name}: {len(text)} chars, args={[tuple(s.shape) for s in shapes]}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # TSV twin for the Rust runtime (std-only, no JSON parser needed):
    # name \t file \t 50x768|768x3072|3072 \t op=linear,c1=592,...
    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# generated by python/compile/aot.py — see runtime::read_manifest\n")
        for a in manifest["artifacts"]:
            shapes = "|".join(
                "x".join(str(d) for d in arg["shape"]) for arg in a["args"]
            )
            meta = ",".join(f"{k}={v}" for k, v in a["meta"].items())
            f.write(f"{a['name']}\t{a['file']}\t{shapes}\t{meta}\n")

    if args.out and first_path:
        # Makefile freshness stamp: copy the first artifact to the stamp path.
        with open(first_path) as src, open(args.out, "w") as dst:
            dst.write(src.read())
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
