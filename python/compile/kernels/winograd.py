"""L1 Pallas Winograd F(2x2,3x3) conv — the TFLite fast path of Fig. 6b.

The paper shows TFLite switching 3x3 convolutions to a Winograd kernel once
Cout exceeds ~128, creating the latency discontinuities its predictor must
model. We implement the same algorithm: input/filter/output transforms plus
the hot-spot — 16 independent transform-domain GEMMs (P x Cin) @ (Cin x
Cout), one per transform position — as a single Pallas kernel with the
transform position as the leading grid dimension.

Implementation note: the transforms are expressed as *Kronecker-product
2-D matmuls* (`vec_row(B^T d B) = (B^T (x) B^T) vec_row(d)`), not as
multi-batch-dim einsums. The einsum formulation produces dot_generals that
the ancient xla_extension 0.5.1 linked by the Rust PJRT runtime miscompiles
(verified by stage-wise bisection; see DESIGN.md §Hardware-Adaptation).
Plain reshapes + 2-D dots round-trip through HLO text correctly.

VMEM per program: (block_p, Cin) V panel + (Cin, block_n) U panel +
(block_p, block_n) M tile — identical budget analysis to matmul.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import _A_T, _B_T, _G

# Kronecker transform matrices (row-major vec convention):
#   vec_row(B^T d B) = (B^T (x) B^T) vec_row(d)
_BT_KRON = np.kron(_B_T, _B_T).astype(np.float32)  # (16, 16)
_AT_KRON = np.kron(_A_T, _A_T).astype(np.float32)  # (4, 16)
_G_KRON = np.kron(_G, _G).astype(np.float32)  # (16, 9)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _wino_gemm_kernel(v_ref, u_ref, m_ref):
    """One transform position t, one (block_p, block_n) tile of M[t] = V[t] @ U[t]."""
    m_ref[...] = jnp.dot(v_ref[0], u_ref[0], preferred_element_type=jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("block_p", "block_n"))
def transform_domain_gemm(
    v: jnp.ndarray, u: jnp.ndarray, *, block_p: int = 512, block_n: int = 256
) -> jnp.ndarray:
    """Batched GEMM over 16 transform positions: (16,P,Cin) @ (16,Cin,Cout)."""
    t, p, cin = v.shape
    _, _, cout = u.shape
    pp, np_ = _round_up(p, block_p), _round_up(cout, block_n)
    vp = jnp.pad(v, ((0, 0), (0, pp - p), (0, 0)))
    up = jnp.pad(u, ((0, 0), (0, 0), (0, np_ - cout)))

    grid = (t, pp // block_p, np_ // block_n)
    out = pl.pallas_call(
        _wino_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_p, cin), lambda tt, i, j: (tt, i, 0)),
            pl.BlockSpec((1, cin, block_n), lambda tt, i, j: (tt, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_p, block_n), lambda tt, i, j: (tt, i, j)),
        out_shape=jax.ShapeDtypeStruct((t, pp, np_), jnp.float32),
        interpret=True,
    )(vp, up)
    return out[:, :p, :cout]


def winograd_filter_transform(w: jnp.ndarray) -> jnp.ndarray:
    """(3,3,Cin,Cout) -> (16,Cin,Cout): U = (G (x) G) vec_row(g)."""
    _, _, cin, cout = w.shape
    wf = w.reshape(9, cin * cout)
    u = jnp.asarray(_G_KRON) @ wf
    return u.reshape(16, cin, cout)


@jax.jit
def winograd_conv3x3(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Winograd F(2x2,3x3), stride 1, SAME. x:(N,H,W,Cin) w:(3,3,Cin,Cout).

    H and W must be even (tile size 2). Numerically ~1e-4 of the direct conv
    (Winograd trades a few ULPs for 2.25x fewer multiplications — the same
    trade TFLite makes, and the reason its kernel switch exists at all).
    """
    n, h, wd, cin = x.shape
    cout = w.shape[-1]

    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    th, tw = h // 2, wd // 2
    p = n * th * tw

    # Gather the 4x4 stride-2 input tiles as 16 strided slices.
    slices = []
    for a in range(4):
        for b in range(4):
            slices.append(
                jax.lax.slice(
                    xp,
                    (0, a, b, 0),
                    (n, a + 2 * (th - 1) + 1, b + 2 * (tw - 1) + 1, cin),
                    (1, 2, 2, 1),
                )
            )
    # tiles[(a*4+b), p*cin] = xp[n, 2ti+a, 2tj+b, c]
    tiles = jnp.stack(slices, axis=0).reshape(16, p * cin)

    # Input transform: one 16x16 matmul over all tiles/channels at once.
    v = (jnp.asarray(_BT_KRON) @ tiles).reshape(16, p, cin)
    # Filter transform -> (16, Cin, Cout)
    u = winograd_filter_transform(w)

    # Hot-spot: 16 GEMMs in Pallas.
    m = transform_domain_gemm(v, u)  # (16, P, Cout)

    # Output transform: 4x16 matmul, then scatter the 2x2 tiles back.
    y = jnp.asarray(_AT_KRON) @ m.reshape(16, p * cout)  # (4, P*Cout)
    y = y.reshape(2, 2, n, th, tw, cout)
    y = jnp.transpose(y, (2, 3, 0, 4, 1, 5))  # (n, th, 2, tw, 2, cout)
    return y.reshape(n, h, wd, cout)
