# L1: Pallas kernels for the paper's compute hot-spots.
#   matmul    — MXU-tiled GEMM (linear layers; conv via im2col)
#   conv2d    — im2col + GEMM (TFLite conv_generic analogue)
#   winograd  — F(2x2,3x3) transform-domain GEMM (TFLite winograd analogue)
#   ref       — pure-jnp oracles asserted by python/tests/
from . import conv2d, matmul, ref, winograd  # noqa: F401
