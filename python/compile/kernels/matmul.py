"""L1 Pallas GEMM kernels — the compute hot-spot of the paper's linear layer.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper drives a
mobile OpenCL GPU where the delegate picks *workgroup* shapes; on TPU the
analogous schedule is the HBM->VMEM ``BlockSpec``: we tile the output into
(block_m x block_n) MXU-friendly tiles (multiples of 128 in the lane dim),
stream full-K panels of X and W into VMEM per tile, and let the MXU consume
bf16/f32 panels. ``interpret=True`` is mandatory on this CPU testbed — real
TPU lowering emits Mosaic custom-calls the CPU PJRT plugin cannot execute.

VMEM budget (documented for the perf model in DESIGN.md §Perf): a
(block_m, K) X panel + (K, block_n) W panel + (block_m, block_n) output tile.
For the flagship ViT shape (50, 768) x (768, 3072) with block 64x256 that is
64*768*4 + 768*256*4 + 64*256*4 bytes ~= 1.0 MiB << 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (block_m, block_n) output tile: full-K panels are resident in VMEM."""
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _matmul_kernel_bias(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32) + b_ref[...]
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    *,
    block_m: int = 64,
    block_n: int = 1024,
) -> jnp.ndarray:
    """Tiled Pallas GEMM: x:(M, K) @ w:(K, N) (+ b:(N,)) -> (M, N).

    Default blocks are sized for the CPU-PJRT testbed (fewer grid steps =
    fewer interpret-mode loop iterations; see EXPERIMENTS.md §Perf): a
    64 x 1024 tile with K=768 is ~3.4 MiB of VMEM, still well inside a
    TPU core's 16 MiB, so the schedule remains TPU-valid.

    Shapes need not be multiples of the block sizes; the wrapper pads to the
    block grid and slices the result (padding contributes zeros to the
    contraction, so numerics are exact).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"

    mp, np_ = _round_up(m, block_m), _round_up(n, block_n)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w

    grid = (mp // block_m, np_ // block_n)
    x_spec = pl.BlockSpec((block_m, k), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((k, block_n), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))

    if b is None:
        out = pl.pallas_call(
            _matmul_kernel,
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, wp)
    else:
        bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b
        b_spec = pl.BlockSpec((1, block_n), lambda i, j: (0, j))
        out = pl.pallas_call(
            _matmul_kernel_bias,
            grid=grid,
            in_specs=[x_spec, w_spec, b_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, wp, bp.reshape(1, -1))
    return out[:m, :n]


def linear_partitioned(
    x: jnp.ndarray,
    w: jnp.ndarray,
    c1: int,
    b: jnp.ndarray | None = None,
    *,
    block_m: int = 64,
    block_n: int = 1024,
) -> jnp.ndarray:
    """The paper's output-channel partitioned linear layer (Section 2).

    Channels [0, c1) are the "CPU" partition, [c1, Cout) the "GPU" partition;
    each runs as an independent Pallas GEMM over its own weight slice (each
    compute unit owns its weights — Fig. 4), and the results are concatenated
    in the shared output buffer. Equal to ``matmul(x, w, b)`` exactly.
    """
    cout = w.shape[1]
    assert 0 <= c1 <= cout
    if c1 == 0 or c1 == cout:
        return matmul(x, w, b, block_m=block_m, block_n=block_n)
    b1 = b[:c1] if b is not None else None
    b2 = b[c1:] if b is not None else None
    y_cpu = matmul(x, w[:, :c1], b1, block_m=block_m, block_n=block_n)
    y_gpu = matmul(x, w[:, c1:], b2, block_m=block_m, block_n=block_n)
    return jnp.concatenate([y_cpu, y_gpu], axis=-1)


def _matmul_kernel_ktiled(x_ref, w_ref, o_ref):
    """K-tiled variant: accumulate into the output tile across the k grid dim.

    Grid is (m, n, k) with k innermost ("arbitrary" semantics in interpret
    mode): the output block for (i, j) is revisited for each k step.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul_ktiled(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_m: int = 64,
    block_n: int = 256,
    block_k: int = 512,
) -> jnp.ndarray:
    """GEMM with an explicit K loop — bounds VMEM for very large Cin.

    VMEM: block_m*block_k + block_k*block_n + block_m*block_n floats, i.e.
    the footprint no longer grows with K (needed once Cin exceeds ~8k).
    """
    m, k = x.shape
    _, n = w.shape
    mp, np_, kp = _round_up(m, block_m), _round_up(n, block_n), _round_up(k, block_k)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))

    grid = (mp // block_m, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        _matmul_kernel_ktiled,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]
