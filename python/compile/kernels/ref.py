"""Pure-jnp correctness oracles for the Pallas kernels.

Each oracle mirrors one Pallas kernel in `kernels/` and defines the
semantics the kernel must reproduce (asserted by pytest + hypothesis in
``python/tests/``). These are also the L2 building blocks of the paper's
partitioned operators:

  * ``linear``            — Y = X W (+ b): the paper's linear layer.
  * ``conv2d``            — NHWC direct convolution, SAME/VALID, stride S.
  * ``winograd_conv3x3``  — F(2x2, 3x3) Winograd convolution, stride 1,
                            the TFLite fast path the paper's Fig. 6b shows
                            kernels switching into (Cout > 128).
  * ``linear_partitioned``/``conv2d_partitioned`` — output-channel split
    [0, c1) on "CPU" and [c1, Cout) on "GPU", concatenated: the identity
    the co-execution engine relies on (Section 2 of the paper).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    """Linear layer: ``x @ w (+ b)`` with x:(L, Cin), w:(Cin, Cout)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    return y


def linear_partitioned(x, w, c1: int, b=None):
    """Channel-partitioned linear: CPU gets w[:, :c1], GPU gets w[:, c1:].

    Returns the concatenated output; must equal ``linear(x, w, b)``.
    """
    w_cpu, w_gpu = w[:, :c1], w[:, c1:]
    if b is None:
        y_cpu = linear(x, w_cpu)
        y_gpu = linear(x, w_gpu)
    else:
        y_cpu = linear(x, w_cpu, b[:c1])
        y_gpu = linear(x, w_gpu, b[c1:])
    return jnp.concatenate([y_cpu, y_gpu], axis=-1)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, padding: str = "SAME") -> jnp.ndarray:
    """Direct 2-D convolution.

    x: (N, H, W, Cin)  w: (K, K, Cin, Cout)  -> (N, H', W', Cout)
    Matches TFLite conv semantics (cross-correlation, NHWC).
    """
    from jax import lax

    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32,
    )


def conv2d_partitioned(x, w, c1: int, stride: int = 1, padding: str = "SAME"):
    """Output-channel partitioned conv: kernels [0,c1) on CPU, rest on GPU."""
    y_cpu = conv2d(x, w[..., :c1], stride, padding)
    y_gpu = conv2d(x, w[..., c1:], stride, padding)
    return jnp.concatenate([y_cpu, y_gpu], axis=-1)


# --- Winograd F(2x2, 3x3) -------------------------------------------------
# Transform matrices (Lavin & Gray 2016). TFLite's winograd path uses
# F(4x4,6x6); we implement the classic F(2x2,3x3) variant — same algorithmic
# structure (input/filter transform, elementwise GEMM in transform domain,
# output transform), smaller tiles.

_B_T = np.array(
    [
        [1, 0, -1, 0],
        [0, 1, 1, 0],
        [0, -1, 1, 0],
        [0, 1, 0, -1],
    ],
    dtype=np.float32,
)
_G = np.array(
    [
        [1, 0, 0],
        [0.5, 0.5, 0.5],
        [0.5, -0.5, 0.5],
        [0, 0, 1],
    ],
    dtype=np.float32,
)
_A_T = np.array(
    [
        [1, 1, 1, 0],
        [0, 1, -1, -1],
    ],
    dtype=np.float32,
)


def winograd_filter_transform(w: jnp.ndarray) -> jnp.ndarray:
    """(3,3,Cin,Cout) -> (4,4,Cin,Cout): U = G g G^T per channel pair."""
    g = jnp.asarray(_G)
    return jnp.einsum("ab,bcio,dc->adio", g, w, g)


def winograd_conv3x3(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Winograd F(2x2,3x3) convolution, stride 1, SAME padding.

    x: (N, H, W, Cin) with H, W even; w: (3, 3, Cin, Cout).
    Equivalent (up to fp error) to ``conv2d(x, w, 1, "SAME")``.
    """
    n, h, wd, cin = x.shape
    assert h % 2 == 0 and wd % 2 == 0, "F(2x2,3x3) needs even spatial dims"
    cout = w.shape[-1]
    bt = jnp.asarray(_B_T)
    at = jnp.asarray(_A_T)

    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    th, tw = h // 2, wd // 2  # number of 2x2 output tiles

    # Gather 4x4 input tiles with stride 2: (n, th, 4, tw, 4, cin)
    i_idx = (jnp.arange(th) * 2)[:, None] + jnp.arange(4)[None, :]  # (th, 4)
    j_idx = (jnp.arange(tw) * 2)[:, None] + jnp.arange(4)[None, :]  # (tw, 4)
    tiles = xp[:, i_idx[:, :, None, None], j_idx[None, None, :, :], :]
    tiles = jnp.transpose(tiles, (0, 1, 3, 2, 4, 5))  # (n, th, tw, 4, 4, cin)

    # Input transform: V = B^T d B
    v = jnp.einsum("ab,nijbcq,dc->nijadq", bt, tiles, bt)
    # Filter transform: U = G g G^T  -> (4,4,cin,cout)
    u = winograd_filter_transform(w)
    # Transform-domain GEMM over cin
    m = jnp.einsum("nijabq,abqo->nijabo", v, u)
    # Output transform: Y = A^T m A  -> 2x2 tiles
    y = jnp.einsum("xa,nijabo,yb->nijxyo", at, m, at)
    # Scatter tiles back: (n, th, tw, 2, 2, cout) -> (n, h, w, cout)
    y = jnp.transpose(y, (0, 1, 3, 2, 4, 5)).reshape(n, h, wd, cout)
    return y


def relu(x):
    return jnp.maximum(x, 0.0)


def maxpool2x2(x):
    """2x2 max pooling, stride 2, NHWC (paper schedules pooling on GPU)."""
    n, h, w, c = x.shape
    return jnp.max(x.reshape(n, h // 2, 2, w // 2, 2, c), axis=(2, 4))
