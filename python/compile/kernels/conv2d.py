"""L1 Pallas conv2d — im2col layout prep (jnp) + Pallas GEMM hot-spot.

TFLite's ``conv_generic`` OpenCL kernel is an implicit-GEMM over
(spatial positions) x (K*K*Cin patches); the TPU-idiomatic equivalent is an
explicit im2col (pure data movement, fused by XLA into the surrounding HLO)
feeding the MXU-tiled Pallas GEMM from ``matmul.py``. The paper's
output-channel partitioning (Section 2) then reduces to column-partitioning
the GEMM's weight matrix — exactly the same split the linear layer uses,
which is why the co-execution engine treats both uniformly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import matmul as mm


def _im2col(x: jnp.ndarray, k: int, stride: int, padding: str) -> tuple[jnp.ndarray, int, int]:
    """(N,H,W,Cin) -> (N*Ho*Wo, K*K*Cin) patch matrix (+ output spatial dims)."""
    n, h, w, cin = x.shape
    if padding == "SAME":
        ho, wo = -(-h // stride), -(-w // stride)
        pad_h = max((ho - 1) * stride + k - h, 0)
        pad_w = max((wo - 1) * stride + k - w, 0)
        x = jnp.pad(
            x,
            (
                (0, 0),
                (pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2),
                (0, 0),
            ),
        )
    elif padding == "VALID":
        ho, wo = (h - k) // stride + 1, (w - k) // stride + 1
    else:
        raise ValueError(f"bad padding {padding!r}")

    # Gather K*K shifted views; XLA fuses these slices into one gather.
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(
                jax.lax.slice(
                    x,
                    (0, di, dj, 0),
                    (n, di + (ho - 1) * stride + 1, dj + (wo - 1) * stride + 1, cin),
                    (1, stride, stride, 1),
                )
            )
    patches = jnp.stack(cols, axis=3)  # (n, ho, wo, K*K, cin)
    return patches.reshape(n * ho * wo, k * k * cin), ho, wo


@functools.partial(jax.jit, static_argnames=("stride", "padding", "block_m", "block_n"))
def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    stride: int = 1,
    padding: str = "SAME",
    block_m: int = 256,
    block_n: int = 256,
) -> jnp.ndarray:
    """Direct conv via im2col + Pallas GEMM. x:(N,H,W,Cin) w:(K,K,Cin,Cout).

    Blocks sized for the CPU-PJRT testbed (256x256 tile + K=k*k*cin panels:
    ~1.3 MiB VMEM at cin=128, k=3 — TPU-valid, few interpret grid steps)."""
    n = x.shape[0]
    k, _, cin, cout = w.shape
    patches, ho, wo = _im2col(x, k, stride, padding)
    wmat = w.reshape(k * k * cin, cout)
    y = mm.matmul(patches, wmat, block_m=block_m, block_n=block_n)
    return y.reshape(n, ho, wo, cout)


def conv2d_partitioned(
    x: jnp.ndarray,
    w: jnp.ndarray,
    c1: int,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jnp.ndarray:
    """Output-channel partitioned conv: kernels [0,c1) on CPU, rest on GPU.

    The im2col patch matrix is computed once and shared by both partitions —
    the analogue of the paper's shared input X in fine-grained SVM.
    """
    n = x.shape[0]
    k, _, cin, cout = w.shape
    assert 0 <= c1 <= cout
    if c1 == 0 or c1 == cout:
        return conv2d(x, w, stride=stride, padding=padding)
    patches, ho, wo = _im2col(x, k, stride, padding)
    wmat = w.reshape(k * k * cin, cout)
    y_cpu = mm.matmul(patches, wmat[:, :c1])
    y_gpu = mm.matmul(patches, wmat[:, c1:])
    y = jnp.concatenate([y_cpu, y_gpu], axis=-1)
    return y.reshape(n, ho, wo, cout)
