import os
import sys

# Make `python/` importable so `pytest python/tests` works from the repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
